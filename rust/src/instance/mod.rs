//! A serving instance: continuous-batching scheduler + paged KV memory +
//! parallelism-aware iteration pricing (§II-B "heterogeneous
//! multi-instance": each instance owns its scheduler and memory model).
//!
//! The engine loop is iteration-level, like vLLM: each step forms a batch
//! of prefill chunks + decode sequences under `max_batch_tokens` /
//! `max_batch_seqs` budgets, prices one full forward pass with the
//! instance's [`PerfModel`], then advances sequence state. TP splits GEMM
//! and attention-head work across devices and pays ring all-reduces; PP is
//! priced as steady-state pipelining (compute / pp + stage-boundary
//! activation hops); EP partitions experts and pays all-to-all dispatch
//! and combine with gate-skew congestion.

pub mod scheduler;

use std::sync::Arc;

use crate::cluster::Lifecycle;
use crate::config::{InstanceConfig, OffloadPolicy, Role};
use crate::memory::{BlockManager, PrefixCache};
use crate::model::{ModelSpec, OpInvocation, OpKind, DTYPE_BYTES};
use crate::moe::{ExpertRouter, OffloadEngine};
use crate::network::{Fabric, Topology};
use crate::perf::{analytical::Roofline, HardwareSpec, PerfModel};
use crate::policy::SchedulePolicy;
use crate::sim::Nanos;
use crate::util::fxhash::FxHashMap;
use crate::workload::Request;

/// The per-instance sequence table, keyed by request id. Deterministic Fx
/// hashing (no per-process entropy); every consumer that *enumerates* it
/// must still impose an explicit order — simlint rule D04 polices that.
pub type SeqMap = FxHashMap<u64, SeqState>;

/// Sequence lifecycle phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// Prompt processing; `done` prompt tokens already prefilled.
    Prefill { done: u64 },
    /// Autoregressive generation; `generated` output tokens emitted.
    Decode { generated: u64 },
}

/// Per-sequence scheduler state.
#[derive(Debug, Clone)]
pub struct SeqState {
    pub req: Request,
    pub phase: Phase,
    /// Prompt tokens whose KV was served by the prefix cache.
    pub cached_tokens: u64,
    /// Host-tier cached tokens (require a host->device KV load).
    pub host_cached_tokens: u64,
    pub enqueued_at: Nanos,
    /// Times this sequence was preempted (recompute restarts).
    pub preemptions: u32,
}

impl SeqState {
    /// Tokens of KV context currently materialized for this sequence.
    pub fn ctx_tokens(&self) -> u64 {
        match self.phase {
            Phase::Prefill { done } => done,
            Phase::Decode { generated } => self.req.prompt_tokens + generated,
        }
    }
}

/// What happened in one engine step.
#[derive(Debug, Clone, Default)]
pub struct StepOutcome {
    /// Step latency (0 if no work).
    pub duration: Nanos,
    /// Requests that emit one token when this step completes.
    pub emitted: Vec<u64>,
    /// Requests that finished generation in this step.
    pub finished: Vec<u64>,
    /// P/D: requests whose prefill completed here and must hand off KV.
    pub handoff: Vec<KvHandoff>,
    /// Requests whose prefill completed this step (any role) — the
    /// coordinator inserts their prompts into the prefix cache.
    pub prefill_done: Vec<Request>,
    /// Requests admitted this step with their any-tier cache hits (metrics).
    pub cache_hits: Vec<(u64, u64)>,
    /// Requests that can NEVER fit this instance's KV pool (rejected).
    pub rejected: Vec<u64>,
    /// True if the step did any work.
    pub work: bool,
}

impl StepOutcome {
    /// Clear for reuse, keeping every buffer's allocation. The coordinator
    /// recycles outcomes through
    /// [`ServingInstance::recycle_outcome`] so steady-state stepping does
    /// no per-step `Vec` allocation.
    pub fn reset(&mut self) {
        self.duration = 0;
        self.emitted.clear();
        self.finished.clear();
        self.handoff.clear();
        self.prefill_done.clear();
        self.cache_hits.clear();
        self.rejected.clear();
        self.work = false;
    }
}

/// KV hand-off descriptor for P/D disaggregation.
#[derive(Debug, Clone)]
pub struct KvHandoff {
    pub req: Request,
    /// Bytes of KV cache to ship to the decode instance.
    pub kv_bytes: u64,
}

/// A single serving instance.
pub struct ServingInstance {
    pub id: usize,
    pub cfg: InstanceConfig,
    pub model: ModelSpec,
    pub hw: HardwareSpec,
    perf: Arc<dyn PerfModel>,
    /// PIM roofline for `OffloadPolicy::Pim` expert pricing.
    pim_perf: Option<Roofline>,
    fabric: Fabric,
    pub blocks: BlockManager,
    expert_router: Option<ExpertRouter>,
    offload: Option<OffloadEngine>,
    /// Wait-queue ordering policy, resolved once at construction (from the
    /// policy registry or injected via the simulation builder).
    sched: Box<dyn SchedulePolicy>,
    wait: Vec<u64>,
    running: Vec<u64>,
    seqs: SeqMap,
    /// Fleet-lifecycle state (DESIGN.md §9); `Active` unless a cluster
    /// controller says otherwise. Only the coordinator mutates this.
    lifecycle: Lifecycle,
    /// Straggler multiplier on step durations (chaos `SetPerfScale` —
    /// DESIGN.md §12). 1.0 = healthy; >1.0 slows every step.
    perf_scale: f64,
    /// Monotone counter for deterministic admission order.
    pub steps: u64,
    pub preemptions: u64,
    // Reused hot-loop buffers (per-step batch bookkeeping + token-id
    // materialization); emptied between uses, never shrunk.
    tok_scratch: Vec<u32>,
    scratch_prefill: Vec<(u64, u64, u64)>,
    scratch_decode: Vec<(u64, u64)>,
    scratch_preempted: Vec<u64>,
    /// A recycled [`StepOutcome`] returned via
    /// [`recycle_outcome`](Self::recycle_outcome).
    spare_out: Option<StepOutcome>,
}

impl ServingInstance {
    /// Build an instance with an already-resolved scheduling policy.
    ///
    /// The coordinator resolves `cfg.sched` (a policy *name*) through the
    /// [`PolicyRegistry`](crate::policy::PolicyRegistry) — or substitutes a
    /// builder-injected custom policy — before calling this, so the
    /// instance itself never touches the registry.
    pub fn new(
        id: usize,
        cfg: InstanceConfig,
        perf: Arc<dyn PerfModel>,
        block_size: u64,
        seed: u64,
        sched: Box<dyn SchedulePolicy>,
    ) -> anyhow::Result<Self> {
        let model = cfg.model_spec()?;
        let hw = cfg.hardware_spec()?;
        cfg.validate()?;

        // KV budget: device memory left after resident weights + headroom.
        // Weights are sharded over tp*pp and replicated over the remaining
        // (data-parallel) device dimension. With expert offloading, expert
        // weights live off-device: only non-expert parameters are resident,
        // and the freed memory is split between the KV pool (40%) and the
        // expert working set (the OffloadEngine derives residency from it).
        let shards = (cfg.tp * cfg.pp).max(1) as u64;
        let replicas = (cfg.devices as u64 / shards).max(1);
        let total_cap = hw.mem_capacity * cfg.devices as u64;
        let expert_total = if model.is_moe() {
            model.moe_layers() * model.experts * model.expert_bytes()
        } else {
            0
        };
        let offloading = model.is_moe() && cfg.offload != OffloadPolicy::None;
        let resident_weights = if offloading {
            (model.param_bytes() - expert_total) * replicas
        } else {
            model.param_bytes() * replicas
        };
        let after_weights = total_cap
            .saturating_sub(resident_weights)
            .saturating_sub(total_cap / 10); // activation headroom
        let kv_budget = if offloading {
            (after_weights as f64 * 0.4) as u64
        } else {
            after_weights
        }
        .max(model.kv_bytes_per_token() * block_size * 8);
        let blocks = BlockManager::new(kv_budget, block_size, model.kv_bytes_per_token());

        let topo = match &cfg.topology {
            crate::config::TopoKind::FullyConnected => {
                Topology::fully_connected(cfg.devices.max(1), hw.mem_bw / 3.0, 1_000)
            }
            crate::config::TopoKind::Ring => {
                Topology::ring(cfg.devices.max(1), hw.mem_bw / 3.0, 1_000)
            }
            crate::config::TopoKind::Switched => {
                Topology::switched(cfg.devices.max(1), hw.mem_bw / 4.0, 2_000)
            }
            crate::config::TopoKind::Hierarchical { nodes, per_node } => {
                Topology::hierarchical(
                    *nodes,
                    *per_node,
                    hw.mem_bw / 3.0,
                    1_000,
                    hw.host_bw,
                    5_000,
                )
            }
        };
        let fabric = Fabric::new(topo);

        let expert_router = if model.is_moe() {
            Some(ExpertRouter::new(
                &model,
                cfg.gate.clone(),
                model.layers,
                seed ^ (id as u64).wrapping_mul(0x9E37),
            ))
        } else {
            None
        };
        let offload = if model.is_moe() {
            Some(OffloadEngine::new(cfg.offload, &model, &hw, kv_budget))
        } else {
            None
        };
        let pim_perf = if cfg.offload == OffloadPolicy::Pim || cfg.af_disagg {
            Some(Roofline::new(HardwareSpec::pim(), model.clone()))
        } else {
            None
        };

        Ok(ServingInstance {
            id,
            cfg,
            model,
            hw,
            perf,
            pim_perf,
            fabric,
            blocks,
            expert_router,
            offload,
            sched,
            wait: vec![],
            running: vec![],
            seqs: SeqMap::default(),
            lifecycle: Lifecycle::Active,
            perf_scale: 1.0,
            steps: 0,
            preemptions: 0,
            tok_scratch: vec![],
            scratch_prefill: vec![],
            scratch_decode: vec![],
            scratch_preempted: vec![],
            spare_out: None,
        })
    }

    /// Hand back a consumed [`StepOutcome`] so the next
    /// [`begin_step`](Self::begin_step) reuses its buffers.
    pub fn recycle_outcome(&mut self, out: StepOutcome) {
        self.spare_out = Some(out);
    }

    /// Name of the resolved wait-queue ordering policy.
    pub fn sched_name(&self) -> &str {
        self.sched.name()
    }

    // ---- lifecycle --------------------------------------------------------

    pub fn lifecycle(&self) -> Lifecycle {
        self.lifecycle
    }

    /// Transition lifecycle state. The coordinator owns the state machine
    /// (`Starting -> Active -> Draining -> Stopped`, `Stopped -> Starting`
    /// on recovery); the instance just records it.
    pub fn set_lifecycle(&mut self, l: Lifecycle) {
        self.lifecycle = l;
    }

    /// Straggler multiplier currently applied to step durations.
    pub fn perf_scale(&self) -> f64 {
        self.perf_scale
    }

    /// Set the straggler multiplier (absolute, not compounding); 1.0
    /// restores nominal speed. Non-finite or non-positive inputs reset to
    /// healthy rather than corrupting every future step duration.
    pub fn set_perf_scale(&mut self, scale: f64) {
        self.perf_scale = if scale.is_finite() && scale > 0.0 {
            scale
        } else {
            1.0
        };
    }

    /// Pull every waiting (not yet admitted) request off this instance for
    /// re-routing, in ascending request-id order. Waiting sequences hold no
    /// KV blocks, so nothing is freed. Used when draining.
    pub fn drain_waiting(&mut self) -> Vec<Request> {
        let mut ids = std::mem::take(&mut self.wait);
        ids.sort_unstable();
        ids.iter()
            .map(|id| {
                self.seqs
                    .remove(id)
                    // simlint: allow(S01) — wait ids are inserted into seqs on enqueue; absence is table corruption
                    .expect("waiting seq missing from table")
                    .req
            })
            .collect()
    }

    /// Hard-failure evacuation: remove *every* resident sequence (running
    /// and waiting), free its KV, and return the requests for re-routing
    /// in ascending id order. Partially decoded sequences are reset
    /// recompute-style (generated tokens fold into the prompt), exactly
    /// like a preemption.
    pub fn evacuate(&mut self) -> Vec<Request> {
        // simlint: allow(D04) — ids are collected then sort_unstable'd before any use
        // simlint: allow(H01) — evacuation runs once per instance failure or
        // drain, not per step; the id snapshot decouples iteration from removal
        let mut ids: Vec<u64> = self.seqs.keys().copied().collect();
        ids.sort_unstable();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            // simlint: allow(S01) — id came from this table's own key set two lines up
            let mut s = self.seqs.remove(&id).expect("seq vanished");
            self.blocks.free_seq(id);
            if let Phase::Decode { generated } = s.phase {
                s.req.prompt_tokens += generated;
                s.req.output_tokens =
                    s.req.output_tokens.saturating_sub(generated).max(1);
            }
            out.push(s.req);
        }
        self.wait.clear();
        self.running.clear();
        out
    }

    // ---- router-visible load signals ------------------------------------

    /// Outstanding requests (waiting + running).
    pub fn outstanding(&self) -> usize {
        self.wait.len() + self.running.len()
    }

    /// Requests waiting for admission.
    pub fn waiting(&self) -> usize {
        self.wait.len()
    }

    /// Sequences in the running batch.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// KV-pool utilization in [0, 1].
    pub fn kv_utilization(&self) -> f64 {
        self.blocks.utilization()
    }

    pub fn has_work(&self) -> bool {
        !self.wait.is_empty() || !self.running.is_empty()
    }

    pub fn fabric_bytes(&self) -> u64 {
        self.fabric.bytes_moved
    }

    // ---- request entry ----------------------------------------------------

    /// Enqueue a fresh request (prefill from scratch).
    pub fn enqueue(&mut self, req: Request, now: Nanos) {
        let id = req.id;
        self.seqs.insert(
            id,
            SeqState {
                req,
                phase: Phase::Prefill { done: 0 },
                cached_tokens: 0,
                host_cached_tokens: 0,
                enqueued_at: now,
                preemptions: 0,
            },
        );
        self.wait.push(id);
    }

    /// Enqueue a request whose prefill happened elsewhere (P/D decode side).
    /// The first output token was already emitted by the prefill instance.
    pub fn enqueue_decoded(&mut self, req: Request, now: Nanos) {
        let id = req.id;
        self.seqs.insert(
            id,
            SeqState {
                req,
                phase: Phase::Decode { generated: 1 },
                cached_tokens: 0,
                host_cached_tokens: 0,
                enqueued_at: now,
                preemptions: 0,
            },
        );
        self.wait.push(id);
    }

    // ---- the engine step ----------------------------------------------------

    /// Run one engine iteration starting at `now`. Mutates scheduler state;
    /// the caller timestamps emissions at `now + outcome.duration`.
    pub fn begin_step(
        &mut self,
        now: Nanos,
        prefix_cache: Option<&mut PrefixCache>,
    ) -> StepOutcome {
        self.steps += 1;
        let mut out = self.spare_out.take().unwrap_or_default();
        out.reset();

        let mut cache = prefix_cache;
        // Without a prefix cache the coordinator never reads
        // `prefill_done`, so skip those `Request` clones entirely.
        let has_cache = cache.is_some();
        self.admit(now, &mut cache, &mut out);
        if self.running.is_empty() {
            return out;
        }
        out.work = true;

        // Partition the running batch, in reused scratch buffers (moved
        // out of `self` so `price_iteration(&mut self, ..)` can borrow).
        let mut prefill = std::mem::take(&mut self.scratch_prefill); // (id, chunk, total_after)
        let mut decode = std::mem::take(&mut self.scratch_decode); // (id, ctx)
        let mut preempted = std::mem::take(&mut self.scratch_preempted);
        prefill.clear();
        decode.clear();
        preempted.clear();
        let mut budget = self.cfg.max_batch_tokens;
        // Decode tokens claim budget first (one per running decode seq).
        for i in 0..self.running.len() {
            let s = &self.seqs[&self.running[i]];
            if matches!(s.phase, Phase::Decode { .. }) {
                decode.push((self.running[i], s.ctx_tokens()));
                budget = budget.saturating_sub(1);
            }
        }
        for i in 0..self.running.len() {
            let id = self.running[i];
            let s = &self.seqs[&id];
            if let Phase::Prefill { done } = s.phase {
                let done_eff = done
                    .max(s.cached_tokens + s.host_cached_tokens)
                    .min(s.req.prompt_tokens);
                let remaining = s.req.prompt_tokens - done_eff;
                if remaining == 0 {
                    // fully cached prompt: completes prefill with a 1-token step
                    prefill.push((id, 1.min(s.req.prompt_tokens), s.req.prompt_tokens));
                    continue;
                }
                let chunk = match self.cfg.chunked_prefill {
                    Some(c) => remaining.min(c).min(budget.max(1)),
                    None => remaining,
                };
                budget = budget.saturating_sub(chunk);
                prefill.push((id, chunk, done_eff + chunk));
            }
        }

        // KV growth for decode seqs; preempt on memory pressure.
        for &(id, _) in &decode {
            let s = &self.seqs[&id];
            let new_total = s.ctx_tokens() + 1;
            if self.blocks.grow_seq(id, new_total).is_err() {
                preempted.push(id);
            }
        }
        for i in 0..preempted.len() {
            self.preempt(preempted[i], now);
        }
        decode.retain(|(id, _)| !preempted.contains(id));
        if decode.is_empty() && prefill.is_empty() {
            out.work = false;
            self.scratch_prefill = prefill;
            self.scratch_decode = decode;
            self.scratch_preempted = preempted;
            return out;
        }

        // Price the iteration.
        let host_load_tokens: u64 = prefill
            .iter()
            .map(|(id, _, _)| self.seqs[id].host_cached_tokens)
            .sum();
        out.duration = self.price_iteration(&prefill, &decode, host_load_tokens, now);
        if self.perf_scale != 1.0 {
            out.duration =
                ((out.duration as f64 * self.perf_scale).round() as Nanos).max(1);
        }

        // Advance state.
        for &(id, _chunk, after) in &prefill {
            let (total, cached) = {
                let s = &self.seqs[&id];
                (s.req.prompt_tokens, s.cached_tokens + s.host_cached_tokens)
            };
            let done_after = (after.max(cached)).min(total);
            if done_after < total {
                // simlint: allow(S01) — id is in running, and running ids always have a seqs entry
                self.seqs.get_mut(&id).unwrap().phase =
                    Phase::Prefill { done: done_after };
                continue;
            }
            // Prefill complete.
            match self.cfg.role {
                Role::Prefill => {
                    // First token emitted here; KV ships to a decode
                    // instance. The sequence is done on this instance, so
                    // the request MOVES into the handoff — no clone.
                    out.emitted.push(id);
                    self.running.retain(|&x| x != id);
                    self.blocks.free_seq(id);
                    // simlint: allow(S01) — id is in running, and running ids always have a seqs entry
                    let st = self.seqs.remove(&id).expect("prefill seq vanished");
                    if has_cache {
                        // simlint: allow(H02) — the prefix cache needs its own
                        // copy (the original moves into the KV handoff below);
                        // taken only at prefill completion with a cache attached
                        out.prefill_done.push(st.req.clone());
                    }
                    let kv_bytes =
                        st.req.prompt_tokens * self.model.kv_bytes_per_token();
                    out.handoff.push(KvHandoff {
                        req: st.req,
                        kv_bytes,
                    });
                }
                _ => {
                    // simlint: allow(S01) — id is in running, and running ids always have a seqs entry
                    let s = self.seqs.get_mut(&id).unwrap();
                    if has_cache {
                        // simlint: allow(H02) — prefix-cache insertion copy,
                        // taken once per request at prefill completion and only
                        // with a cache attached; the sequence itself keeps `req`
                        out.prefill_done.push(s.req.clone());
                    }
                    s.phase = Phase::Decode { generated: 1 };
                    out.emitted.push(id);
                    if s.req.output_tokens <= 1 {
                        out.finished.push(id);
                        self.running.retain(|&x| x != id);
                        self.blocks.free_seq(id);
                        self.seqs.remove(&id);
                    }
                }
            }
        }
        for &(id, _) in &decode {
            // simlint: allow(S01) — id is in the decode partition built from seqs this step
            let s = self.seqs.get_mut(&id).unwrap();
            if let Phase::Decode { generated } = s.phase {
                let g = generated + 1;
                s.phase = Phase::Decode { generated: g };
                out.emitted.push(id);
                if g >= s.req.output_tokens {
                    out.finished.push(id);
                    self.running.retain(|&x| x != id);
                    self.blocks.free_seq(id);
                    self.seqs.remove(&id);
                }
            }
        }
        self.scratch_prefill = prefill;
        self.scratch_decode = decode;
        self.scratch_preempted = preempted;
        out
    }

    /// Admit waiting sequences into the running batch.
    fn admit(
        &mut self,
        now: Nanos,
        cache: &mut Option<&mut PrefixCache>,
        out: &mut StepOutcome,
    ) {
        self.sched.order(&mut self.wait, &self.seqs, now);
        // Reject sequences that can never fit the pool (they would block
        // the head of the queue forever).
        let total = self.blocks.total_blocks();
        let impossible: Vec<u64> = self
            .wait
            .iter()
            .filter(|id| {
                let s = &self.seqs[id];
                let need = s.ctx_tokens().max(s.req.prompt_tokens) + 1;
                self.blocks.blocks_for(need) > total
            })
            .copied()
            // simlint: allow(H01) — rejection list: empty in any sane config
            // (an empty collect never allocates); only requests too large for
            // the whole pool ever populate it
            .collect();
        for id in impossible {
            log::error!(
                "request {id} needs more KV than instance {} ever has; rejecting",
                self.id
            );
            self.wait.retain(|&x| x != id);
            self.seqs.remove(&id);
            out.rejected.push(id);
        }
        // The admission loop only ever accepts a *prefix* of the ordered
        // wait queue (every reject is a `break`), so admitted ids can be
        // drained in one splice instead of a retain() per id.
        let mut admitted = 0usize;
        let mut prefill_budget = self.cfg.max_batch_tokens;
        let mut free_blocks = self.blocks.free_blocks();
        while admitted < self.wait.len() {
            if self.running.len() + admitted >= self.cfg.max_batch_seqs {
                break;
            }
            let s = &self.seqs[&self.wait[admitted]];
            let need_tokens = s.ctx_tokens().max(s.req.prompt_tokens) + 1;
            let need_blocks = self.blocks.blocks_for(need_tokens);
            if need_blocks > free_blocks {
                break; // FCFS head-of-line: don't skip ahead of a blocked seq
            }
            free_blocks -= need_blocks;
            // Budget check: prompt must fit the batch token budget unless it
            // is the only prefill (vLLM admits oversized prompts alone).
            if matches!(s.phase, Phase::Prefill { .. }) {
                let want = s.req.prompt_tokens.min(
                    self.cfg.chunked_prefill.unwrap_or(s.req.prompt_tokens),
                );
                if want > prefill_budget && admitted > 0 {
                    break;
                }
                prefill_budget = prefill_budget.saturating_sub(want);
            }
            admitted += 1;
        }
        for id in self.wait.drain(..admitted) {
            // Prefix-cache lookup at admission (prefill seqs only).
            // simlint: allow(S01) — id was drained from wait, and wait ids always have a seqs entry
            let s = self.seqs.get_mut(&id).unwrap();
            if matches!(s.phase, Phase::Prefill { done: 0 }) && s.preemptions == 0 {
                if let Some(c) = cache.as_deref_mut() {
                    s.req.fill_token_ids(&mut self.tok_scratch);
                    let hit = c.lookup(&self.tok_scratch, now);
                    // never cache-skip the whole prompt: the last token must
                    // be recomputed to produce the first output logits
                    let max_skip = s.req.prompt_tokens.saturating_sub(1);
                    s.cached_tokens = hit.device_tokens.min(max_skip);
                    s.host_cached_tokens =
                        hit.host_tokens.min(max_skip - s.cached_tokens.min(max_skip));
                    if hit.total() > 0 {
                        out.cache_hits.push((id, s.cached_tokens + s.host_cached_tokens));
                    }
                }
            }
            let total = self.seqs[&id].ctx_tokens().max(self.seqs[&id].req.prompt_tokens) + 1;
            self.blocks
                .allocate_seq(id, total, &[])
                // simlint: allow(S01) — admit() pre-checked can_allocate for this sequence
                .expect("admission checked can_allocate");
            self.running.push(id);
        }
    }

    /// Preempt a decode sequence (vLLM recompute-style): free its KV and
    /// move it back to the wait queue; generated tokens become prompt.
    fn preempt(&mut self, id: u64, _now: Nanos) {
        self.blocks.free_seq(id);
        self.running.retain(|&x| x != id);
        // simlint: allow(S01) — preempt is only called with a resident running id
        let s = self.seqs.get_mut(&id).unwrap();
        if let Phase::Decode { generated } = s.phase {
            s.req.prompt_tokens += generated;
            s.req.output_tokens = s.req.output_tokens.saturating_sub(generated).max(1);
        }
        s.phase = Phase::Prefill { done: 0 };
        s.cached_tokens = 0;
        s.host_cached_tokens = 0;
        s.preemptions += 1;
        self.preemptions += 1;
        self.wait.insert(0, id);
    }

    /// Insert a finished prompt into the prefix cache (post-prefill, §II-D).
    pub fn cache_insert(&mut self, cache: &mut PrefixCache, req: &Request, now: Nanos) {
        req.fill_token_ids(&mut self.tok_scratch);
        cache.insert(&self.tok_scratch, now);
    }

    // ---- iteration pricing -------------------------------------------------

    /// Price one forward pass over the batch.
    fn price_iteration(
        &mut self,
        prefill: &[(u64, u64, u64)],
        decode: &[(u64, u64)],
        host_load_tokens: u64,
        now: Nanos,
    ) -> Nanos {
        let tp = self.cfg.tp.max(1) as u64;
        let pp = self.cfg.pp.max(1) as u64;
        let ep = self.cfg.ep.max(1) as u64;
        let h = self.model.hidden;

        let t_prefill: u64 = prefill.iter().map(|(_, c, _)| *c).sum();
        let b_decode = decode.len() as u64;
        let t_total = (t_prefill + b_decode).max(1);

        let p = |inv: OpInvocation| -> Nanos { self.perf.op_latency(inv) };
        // Attention/FFN disaggregation: attention ops run on the PIM-like
        // memory device; activations hop across the host link per layer.
        let af = self.cfg.af_disagg;
        let p_attn = |inv: OpInvocation| -> Nanos {
            match (&self.pim_perf, af) {
                (Some(pim), true) => pim.op_latency(inv),
                _ => self.perf.op_latency(inv),
            }
        };

        // --- attention + projections, one layer ---
        let mut layer = 0u64;
        layer += p(OpInvocation::tokens(OpKind::RmsNorm, t_total)) * 2;
        layer += p(OpInvocation::tokens(OpKind::QkvProj, t_total)) / tp;
        for (_, chunk, after) in prefill {
            // chunk attends to all `after` context tokens; heads split by TP
            let seq = (*after).max(*chunk);
            layer += p_attn(OpInvocation::prefill(seq)) / tp;
        }
        if b_decode > 0 {
            let mean_ctx =
                decode.iter().map(|(_, c)| *c).sum::<u64>() / b_decode.max(1);
            layer += p_attn(OpInvocation::decode(b_decode, mean_ctx.max(1))) / tp;
        }
        layer += p(OpInvocation::tokens(OpKind::OutProj, t_total)) / tp;
        if af {
            // QKV ship to the attention device and outputs return.
            let act_bytes = 2 * t_total * h * DTYPE_BYTES;
            layer += (act_bytes as f64 / self.hw.host_bw * 1e9).round() as Nanos;
        }

        // --- FFN / MoE, one layer ---
        let mut moe_layer_extra = 0u64;
        let is_moe = self.model.is_moe();
        if is_moe {
            moe_layer_extra += p(OpInvocation::tokens(OpKind::MoeGate, t_total));
            // Route once for a representative layer; per-layer permutations
            // are averaged by pricing the actual per-layer routes below.
        } else {
            layer += p(OpInvocation::tokens(OpKind::Ffn, t_total)) / tp;
        }

        // TP all-reduces: one after attention, one after FFN.
        let mut comm = 0u64;
        if tp > 1 {
            let bytes = t_total * h * DTYPE_BYTES;
            let t0 = self.fabric.all_reduce(tp as usize, bytes, now);
            comm += (t0 - now) * 2;
        }

        // --- compose layers ---
        let layers = self.model.layers;
        let mut total = 0u64;
        if is_moe {
            for l in 0..layers {
                let outcome = self
                    .expert_router
                    .as_mut()
                    // simlint: allow(S01) — is_moe guarantees expert_router was constructed
                    .unwrap()
                    .route(l, t_total);
                let skew = outcome.skew();
                // Experts partitioned round-robin over EP groups; the layer
                // waits for the slowest group.
                // simlint: allow(H01) — `ep`-sized (a handful of groups), MoE
                // pricing only; hoisting would need interior mutability on a
                // `&self` pricing path, which costs more than the allocation
                let mut group_cost = vec![0u64; ep as usize];
                for (e, &tok) in outcome.tokens_per_expert.iter().enumerate() {
                    if tok == 0 {
                        continue;
                    }
                    let g = e % ep as usize;
                    let inv = OpInvocation::tokens(OpKind::ExpertFfn, tok);
                    let cost = match (&self.offload, &self.pim_perf) {
                        (Some(off), Some(pim)) if off.policy == OffloadPolicy::Pim => {
                            pim.op_latency(inv)
                        }
                        _ => self.perf.op_latency(inv),
                    };
                    group_cost[g] += cost / (tp / ep.min(tp)).max(1);
                }
                let expert_cost = group_cost.iter().copied().max().unwrap_or(0);
                let mut l_cost = layer + moe_layer_extra + expert_cost;
                // EP all-to-all: dispatch + combine.
                if ep > 1 {
                    let bytes_per_pair =
                        (t_total * h * DTYPE_BYTES) / (ep * ep).max(1);
                    let t0 = self.fabric.all_to_all(
                        ep as usize,
                        bytes_per_pair.max(1),
                        skew,
                        now,
                    );
                    l_cost += (t0 - now) * 2;
                }
                // Offloading cost for this layer's active experts.
                if let Some(off) = &self.offload {
                    let c = off.layer_cost(outcome.active_experts(), l_cost);
                    l_cost += c.exposed_ns;
                    if c.compute_remote {
                        // activations to/from the PIM device
                        let act_bytes = 2 * t_total * h * DTYPE_BYTES;
                        l_cost +=
                            (act_bytes as f64 / self.hw.host_bw * 1e9).round() as Nanos;
                    }
                }
                total += l_cost + comm;
            }
        } else {
            total = (layer + comm) * layers;
        }

        // LM head over last-token logits only (decode tokens + prompts
        // completing prefill this step).
        let lm_tokens = b_decode
            + prefill
                .iter()
                .filter(|(id, _, after)| *after >= self.seqs[id].req.prompt_tokens)
                .count() as u64;
        if lm_tokens > 0 {
            total += p(OpInvocation::tokens(OpKind::LmHead, lm_tokens)) / tp;
            total += p(OpInvocation::tokens(OpKind::RmsNorm, lm_tokens));
        }

        // Pipeline parallelism: steady-state pipelining divides compute,
        // plus per-boundary activation hops.
        if pp > 1 {
            let hop_bytes = t_total * h * DTYPE_BYTES;
            let hop =
                (hop_bytes as f64 / (self.hw.mem_bw / 3.0) * 1e9).round() as Nanos;
            total = total / pp + hop * (pp - 1);
        }

        // Host->device KV loads for host-tier prefix hits.
        if host_load_tokens > 0 {
            let bytes = host_load_tokens * self.model.kv_bytes_per_token();
            total += (bytes as f64 / self.hw.host_bw * 1e9).round() as Nanos;
        }

        total.max(1)
    }

    /// Test/introspection access to a sequence.
    pub fn seq(&self, id: u64) -> Option<&SeqState> {
        self.seqs.get(&id)
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        self.blocks.check_invariants()?;
        for id in &self.running {
            if !self.seqs.contains_key(id) {
                return Err(format!("running seq {id} missing from table"));
            }
            if self.blocks.seq_blocks(*id).is_none() {
                return Err(format!("running seq {id} has no KV blocks"));
            }
        }
        for id in &self.wait {
            if !self.seqs.contains_key(id) {
                return Err(format!("waiting seq {id} missing from table"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;
    use crate::perf::analytical::Roofline;
    use scheduler::{Fcfs, Sjf};

    fn req(id: u64, arrival: Nanos, prompt: u64, output: u64) -> Request {
        Request {
            id,
            arrival,
            prompt_tokens: prompt,
            output_tokens: output,
            session: id,
            ..Request::default()
        }
    }

    fn dense_instance() -> ServingInstance {
        let cfg = InstanceConfig::basic("t", "tiny-dense", "rtx3090");
        let perf = Arc::new(Roofline::new(
            HardwareSpec::rtx3090(),
            ModelSpec::tiny_dense(),
        ));
        ServingInstance::new(0, cfg, perf, 16, 1, Box::new(Fcfs)).unwrap()
    }

    fn moe_instance(offload: OffloadPolicy) -> ServingInstance {
        let mut cfg = InstanceConfig::basic("m", "tiny-moe", "rtx3090");
        cfg.gate = GateKind::Zipf { s: 1.0 };
        cfg.offload = offload;
        let perf = Arc::new(Roofline::new(
            HardwareSpec::rtx3090(),
            ModelSpec::tiny_moe(),
        ));
        ServingInstance::new(0, cfg, perf, 16, 1, Box::new(Fcfs)).unwrap()
    }

    /// Drive an instance until a request finishes or the step budget runs out.
    fn run_to_completion(inst: &mut ServingInstance, max_steps: usize) -> Vec<u64> {
        let mut now = 0;
        let mut finished = vec![];
        for _ in 0..max_steps {
            let out = inst.begin_step(now, None);
            if !out.work {
                break;
            }
            now += out.duration;
            finished.extend(out.finished);
            inst.check_invariants().unwrap();
        }
        finished
    }

    #[test]
    fn single_request_lifecycle() {
        let mut inst = dense_instance();
        inst.enqueue(req(0, 0, 64, 4), 0);
        let out = inst.begin_step(0, None);
        assert!(out.work);
        assert!(out.duration > 0);
        // prefill completes in step 1 → first token
        assert_eq!(out.emitted, vec![0]);
        assert!(out.finished.is_empty());
        // three more decode steps
        let finished = run_to_completion(&mut inst, 10);
        assert_eq!(finished, vec![0]);
        assert_eq!(inst.outstanding(), 0);
        assert_eq!(inst.blocks.used_blocks(), 0);
    }

    #[test]
    fn batch_decodes_together() {
        let mut inst = dense_instance();
        for i in 0..4 {
            inst.enqueue(req(i, 0, 32, 8), 0);
        }
        let out = inst.begin_step(0, None);
        assert_eq!(out.emitted.len(), 4, "all prefills complete in one batch");
        let out2 = inst.begin_step(out.duration, None);
        assert_eq!(out2.emitted.len(), 4, "batched decode emits 4 tokens");
    }

    #[test]
    fn perf_scale_stretches_step_durations() {
        let mut healthy = dense_instance();
        let mut slow = dense_instance();
        slow.set_perf_scale(2.5);
        healthy.enqueue(req(0, 0, 128, 4), 0);
        slow.enqueue(req(0, 0, 128, 4), 0);
        let a = healthy.begin_step(0, None).duration;
        let b = slow.begin_step(0, None).duration;
        assert_eq!(b, ((a as f64 * 2.5).round() as Nanos).max(1));
        // absolute, not compounding; 1.0 restores nominal speed
        slow.set_perf_scale(1.0);
        let c = slow.begin_step(b, None).duration;
        let d = healthy.begin_step(a, None).duration;
        assert_eq!(c, d, "scale reset must restore nominal pricing");
        // degenerate inputs reset to healthy instead of poisoning steps
        slow.set_perf_scale(f64::NAN);
        assert_eq!(slow.perf_scale(), 1.0);
        slow.set_perf_scale(-3.0);
        assert_eq!(slow.perf_scale(), 1.0);
    }

    #[test]
    fn decode_step_faster_than_prefill() {
        let mut inst = dense_instance();
        inst.enqueue(req(0, 0, 512, 4), 0);
        let prefill = inst.begin_step(0, None);
        let decode = inst.begin_step(prefill.duration, None);
        assert!(
            decode.duration < prefill.duration,
            "decode {} !< prefill {}",
            decode.duration,
            prefill.duration
        );
    }

    #[test]
    fn max_batch_seqs_respected() {
        let mut inst = dense_instance();
        inst.cfg.max_batch_seqs = 2;
        for i in 0..5 {
            inst.enqueue(req(i, 0, 16, 2), 0);
        }
        let out = inst.begin_step(0, None);
        assert_eq!(out.emitted.len(), 2);
        assert_eq!(inst.outstanding(), 5); // 2 running + 3 waiting
    }

    #[test]
    fn prefill_role_hands_off() {
        let mut inst = dense_instance();
        inst.cfg.role = Role::Prefill;
        inst.enqueue(req(0, 0, 64, 8), 0);
        let out = inst.begin_step(0, None);
        assert_eq!(out.handoff.len(), 1);
        assert_eq!(out.emitted, vec![0]); // first token from prefill
        let h = &out.handoff[0];
        assert_eq!(
            h.kv_bytes,
            64 * ModelSpec::tiny_dense().kv_bytes_per_token()
        );
        // request left this instance entirely
        assert_eq!(inst.outstanding(), 0);
        assert_eq!(inst.blocks.used_blocks(), 0);
    }

    #[test]
    fn decode_role_accepts_handoff() {
        let mut inst = dense_instance();
        inst.cfg.role = Role::Decode;
        inst.enqueue_decoded(req(0, 0, 64, 4), 0);
        let finished = run_to_completion(&mut inst, 10);
        assert_eq!(finished, vec![0]);
    }

    #[test]
    fn memory_pressure_preempts_and_recovers() {
        let mut inst = dense_instance();
        // Shrink the pool: enough for ~2 long sequences
        inst.blocks = BlockManager::new(
            40 * 16 * ModelSpec::tiny_dense().kv_bytes_per_token(),
            16,
            ModelSpec::tiny_dense().kv_bytes_per_token(),
        );
        for i in 0..4 {
            inst.enqueue(req(i, 0, 256, 64), 0);
        }
        let finished = run_to_completion(&mut inst, 500);
        assert_eq!(finished.len(), 4, "all requests eventually finish");
        assert_eq!(inst.blocks.used_blocks(), 0);
    }

    #[test]
    fn moe_pricing_exceeds_dense() {
        let mut d = dense_instance();
        let mut m = moe_instance(OffloadPolicy::None);
        d.enqueue(req(0, 0, 128, 4), 0);
        m.enqueue(req(0, 0, 128, 4), 0);
        let ld = d.begin_step(0, None).duration;
        let lm = m.begin_step(0, None).duration;
        // tiny-moe activates top_k*expert_ffn == dense ffn FLOPs, plus gate
        // overhead → MoE step must not be cheaper
        assert!(lm >= ld, "moe {lm} < dense {ld}");
    }

    #[test]
    fn offload_on_demand_slower_when_memory_tight() {
        let mut none = moe_instance(OffloadPolicy::None);
        let mut od = moe_instance(OffloadPolicy::OnDemand);
        // force low residency
        if let Some(off) = &mut od.offload {
            off.resident_fraction = 0.25;
        }
        none.enqueue(req(0, 0, 128, 2), 0);
        od.enqueue(req(0, 0, 128, 2), 0);
        let a = none.begin_step(0, None).duration;
        let b = od.begin_step(0, None).duration;
        assert!(b > a, "on-demand {b} !> resident {a}");
    }

    #[test]
    fn prefix_cache_reduces_prefill_latency() {
        // Use an overhead-free perf model: the tiny model is kernel-launch
        // bound on GPU specs, which would mask the compute saving.
        let mut inst = dense_instance();
        let mut hw = HardwareSpec::rtx3090();
        hw.kernel_overhead = 0;
        inst.perf = Arc::new(Roofline::new(hw, ModelSpec::tiny_dense()));
        let mut cache = PrefixCache::new(1 << 20, 1 << 20, crate::memory::EvictPolicy::Lru);
        let mut r1 = req(0, 0, 256, 2);
        r1.session = 7;
        r1.shared_prefix = 255;
        let mut r2 = req(1, 0, 256, 2);
        r2.session = 7;
        r2.shared_prefix = 255;

        inst.enqueue(r1.clone(), 0);
        let cold = inst.begin_step(0, Some(&mut cache)).duration;
        inst.cache_insert(&mut cache, &r1, 1);
        run_to_completion(&mut inst, 10);

        inst.enqueue(r2, 0);
        let mut out = StepOutcome::default();
        std::mem::swap(&mut out, &mut inst.begin_step(cold, Some(&mut cache)));
        assert!(
            out.duration < cold / 2,
            "cached prefill {} !<< cold {}",
            out.duration,
            cold
        );
        assert!(!out.cache_hits.is_empty());
    }

    #[test]
    fn tp_reduces_iteration_latency() {
        let mk = |tp: usize| {
            let mut cfg = InstanceConfig::basic("t", "tiny-dense", "rtx3090");
            cfg.devices = tp;
            cfg.tp = tp;
            let perf = Arc::new(Roofline::new(
                HardwareSpec::rtx3090(),
                ModelSpec::tiny_dense(),
            ));
            ServingInstance::new(0, cfg, perf, 16, 1, Box::new(Fcfs)).unwrap()
        };
        let mut a = mk(1);
        let mut b = mk(2);
        a.enqueue(req(0, 0, 512, 2), 0);
        b.enqueue(req(0, 0, 512, 2), 0);
        let la = a.begin_step(0, None).duration;
        let lb = b.begin_step(0, None).duration;
        assert!(lb < la, "tp2 {lb} !< tp1 {la}");
    }

    #[test]
    fn evacuate_resets_decode_recompute_style() {
        let mut inst = dense_instance();
        assert!(inst.lifecycle().is_active());
        inst.enqueue(req(0, 0, 64, 8), 0);
        inst.enqueue(req(1, 0, 32, 4), 0);
        // run two steps: seq 0/1 finish prefill + one decode token each
        let out = inst.begin_step(0, None);
        inst.begin_step(out.duration, None);
        let evacuated = inst.evacuate();
        assert_eq!(evacuated.len(), 2);
        assert_eq!(evacuated[0].id, 0, "ascending id order");
        // 2 tokens generated folded into the prompt, output shrunk
        assert_eq!(evacuated[0].prompt_tokens, 66);
        assert_eq!(evacuated[0].output_tokens, 6);
        assert_eq!(inst.outstanding(), 0);
        assert_eq!(inst.blocks.used_blocks(), 0);
        inst.check_invariants().unwrap();
    }

    #[test]
    fn drain_waiting_leaves_running_batch() {
        let mut inst = dense_instance();
        inst.cfg.max_batch_seqs = 1;
        for i in 0..3 {
            inst.enqueue(req(i, 0, 16, 4), 0);
        }
        inst.begin_step(0, None); // admits seq 0 only
        let displaced = inst.drain_waiting();
        assert_eq!(
            displaced.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(inst.waiting(), 0);
        assert_eq!(inst.running_count(), 1, "running batch keeps draining");
        inst.check_invariants().unwrap();
        inst.set_lifecycle(Lifecycle::Draining);
        assert!(inst.lifecycle().can_run());
        let finished = run_to_completion(&mut inst, 20);
        assert_eq!(finished, vec![0]);
    }

    #[test]
    fn scheduler_sjf_prefers_short_prompts() {
        let mut cfg = InstanceConfig::basic("t", "tiny-dense", "rtx3090");
        cfg.max_batch_seqs = 1;
        let perf = Arc::new(Roofline::new(
            HardwareSpec::rtx3090(),
            ModelSpec::tiny_dense(),
        ));
        let mut inst =
            ServingInstance::new(0, cfg, perf, 16, 1, Box::new(Sjf)).unwrap();
        assert_eq!(inst.sched_name(), "sjf");
        inst.enqueue(req(0, 0, 512, 2), 0);
        inst.enqueue(req(1, 0, 16, 2), 0);
        let out = inst.begin_step(0, None);
        assert_eq!(out.emitted, vec![1], "short prompt admitted first");
    }
}
