//! Wait-queue ordering policies for the continuous-batching scheduler.
//!
//! The paper exposes scheduling as a customizable policy (§II-B). The
//! decision point is the [`SchedulePolicy`] trait; the three classical
//! orders below back the registry's `fcfs`, `sjf`, and `priority` entries.
//! All built-in orders are stable and deterministic: ties break on request
//! id, and sequences that were preempted mid-decode always sort first
//! (vLLM semantics: recompute victims re-enter ahead of fresh arrivals so
//! their already-emitted tokens don't stall indefinitely). Custom policies
//! implement the trait in their own file and register via
//! [`crate::policy::register_sched_policy`] — no edits here required.

use crate::policy::SchedulePolicy;
use crate::sim::Nanos;

use super::{Phase, SeqMap, SeqState};

/// First-come-first-served admission (vLLM default).
#[derive(Debug, Default)]
pub struct Fcfs;

impl SchedulePolicy for Fcfs {
    fn name(&self) -> &str {
        "fcfs"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, _now: Nanos) {
        wait.sort_by_key(|id| {
            let s = &seqs[id];
            (priority_class(s), s.enqueued_at, s.req.id)
        });
    }
}

/// Shortest prompt first.
#[derive(Debug, Default)]
pub struct Sjf;

impl SchedulePolicy for Sjf {
    fn name(&self) -> &str {
        "sjf"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, _now: Nanos) {
        wait.sort_by_key(|id| {
            let s = &seqs[id];
            (priority_class(s), s.req.prompt_tokens, s.req.id)
        });
    }
}

/// Shortest-job-first weighted by waiting time: rank =
/// `prompt_tokens / (1 + waited_ms)`. Long waiters bubble up
/// (anti-starvation SJF hybrid).
#[derive(Debug, Default)]
pub struct Priority;

impl SchedulePolicy for Priority {
    fn name(&self) -> &str {
        "priority"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, now: Nanos) {
        wait.sort_by(|a, b| {
            let ra = rank(&seqs[a], now);
            let rb = rank(&seqs[b], now);
            (priority_class(&seqs[a]), ra, seqs[a].req.id)
                .partial_cmp(&(priority_class(&seqs[b]), rb, seqs[b].req.id))
                // simlint: allow(S01) — rank() is a ratio of finite non-negative values, never NaN
                .unwrap()
        });
    }
}

/// Earliest-TTFT-deadline-first over the request SLO classes: each
/// sequence's deadline is `arrival + slo_class.ttft_target_ns()`, so
/// interactive requests overtake batch requests until a batch request's
/// (much later) deadline finally comes due — EDF with two classes, and the
/// anti-starvation property falls out of the deadline arithmetic.
#[derive(Debug, Default)]
pub struct SloDeadline;

impl SchedulePolicy for SloDeadline {
    fn name(&self) -> &str {
        "slo"
    }
    fn order(&mut self, wait: &mut [u64], seqs: &SeqMap, _now: Nanos) {
        wait.sort_by_key(|id| {
            let s = &seqs[id];
            (priority_class(s), deadline(s), s.req.id)
        });
    }
}

/// TTFT deadline of a sequence (saturating).
pub fn deadline(s: &SeqState) -> Nanos {
    s.req
        .arrival
        .saturating_add(s.req.slo_class.ttft_target_ns())
}

/// Admission class shared by the built-in orders: preemption victims first,
/// then P/D hand-offs (already holding a user stream), then fresh prefills.
pub fn priority_class(s: &SeqState) -> u8 {
    match s.phase {
        _ if s.preemptions > 0 => 0,
        Phase::Decode { .. } => 1, // P/D handoffs: already holding a user stream
        Phase::Prefill { .. } => 2,
    }
}

fn rank(s: &SeqState, now: Nanos) -> f64 {
    let waited_ms = (now.saturating_sub(s.enqueued_at)) as f64 / 1e6;
    s.req.prompt_tokens as f64 / (1.0 + waited_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Request;

    fn seq(id: u64, prompt: u64, enq: Nanos) -> (u64, SeqState) {
        (
            id,
            SeqState {
                req: Request {
                    id,
                    arrival: enq,
                    prompt_tokens: prompt,
                    output_tokens: 4,
                    session: id,
                    ..Request::default()
                },
                phase: Phase::Prefill { done: 0 },
                cached_tokens: 0,
                host_cached_tokens: 0,
                enqueued_at: enq,
                preemptions: 0,
            },
        )
    }

    fn builtin_policies() -> Vec<Box<dyn SchedulePolicy>> {
        vec![
            Box::new(Fcfs),
            Box::new(Sjf),
            Box::new(Priority),
            Box::new(SloDeadline),
        ]
    }

    #[test]
    fn fcfs_orders_by_arrival() {
        let seqs: SeqMap =
            [seq(0, 10, 300), seq(1, 10, 100), seq(2, 10, 200)].into_iter().collect();
        let mut wait = vec![0, 1, 2];
        Fcfs.order(&mut wait, &seqs, 1000);
        assert_eq!(wait, vec![1, 2, 0]);
    }

    #[test]
    fn sjf_orders_by_prompt() {
        let seqs: SeqMap =
            [seq(0, 300, 0), seq(1, 50, 0), seq(2, 100, 0)].into_iter().collect();
        let mut wait = vec![0, 1, 2];
        Sjf.order(&mut wait, &seqs, 0);
        assert_eq!(wait, vec![1, 2, 0]);
    }

    #[test]
    fn preempted_always_first() {
        let mut m: SeqMap = [seq(0, 10, 0), seq(1, 999, 500)].into_iter().collect();
        m.get_mut(&1).unwrap().preemptions = 1;
        let mut wait = vec![0, 1];
        for mut p in builtin_policies() {
            p.order(&mut wait, &m, 1000);
            assert_eq!(wait[0], 1, "policy {}", p.name());
        }
    }

    #[test]
    fn priority_ages_long_waiters() {
        // long prompt waiting a long time beats short prompt that just came
        let seqs: SeqMap =
            [seq(0, 512, 0), seq(1, 64, 999_000_000)].into_iter().collect();
        let mut wait = vec![0, 1];
        Priority.order(&mut wait, &seqs, 1_000_000_000);
        assert_eq!(wait[0], 0, "aged long prompt should rank first");
    }

    #[test]
    fn slo_prefers_interactive_until_batch_deadline_passes() {
        use crate::workload::SloClass;
        // batch arrived first, interactive second: EDF still runs the
        // interactive request first (tighter TTFT target).
        let mut m: SeqMap = [seq(0, 10, 0), seq(1, 10, 1000)].into_iter().collect();
        m.get_mut(&0).unwrap().req.slo_class = SloClass::Batch;
        let mut wait = vec![0, 1];
        SloDeadline.order(&mut wait, &m, 2000);
        assert_eq!(wait, vec![1, 0]);

        // but a batch request whose deadline comes due beats a much newer
        // interactive request (no starvation).
        let late = SloClass::Batch.ttft_target_ns() + 1000;
        let mut m: SeqMap = [seq(0, 10, 0), seq(1, 10, late)].into_iter().collect();
        m.get_mut(&0).unwrap().req.slo_class = SloClass::Batch;
        let mut wait = vec![1, 0];
        SloDeadline.order(&mut wait, &m, late);
        assert_eq!(wait, vec![0, 1], "aged batch deadline must win");
    }

    #[test]
    fn deterministic_tiebreak() {
        let seqs: SeqMap = [seq(3, 10, 0), seq(1, 10, 0), seq(2, 10, 0)].into_iter().collect();
        let mut wait = vec![3, 1, 2];
        Fcfs.order(&mut wait, &seqs, 0);
        assert_eq!(wait, vec![1, 2, 3]);
    }

    #[test]
    fn names_match_registry_keys() {
        for p in builtin_policies() {
            assert!(
                crate::policy::PolicyRegistry::builtins().has_sched(p.name()),
                "builtin sched '{}' missing from registry",
                p.name()
            );
        }
    }
}
