//! Cluster controllers: the fourth plugin axis (DESIGN.md §9).
//!
//! The first three axes (policies, traffic, hardware) decide *how* a fixed
//! fleet serves requests. This axis opens the fleet itself: a
//! [`ClusterController`] is invoked on a configurable tick with a
//! read-only [`ClusterView`] snapshot and returns typed [`ClusterAction`]s
//! — scale up, drain, fail, recover, retune — that the coordinator applies
//! between events. Instances gain a lifecycle
//! (`Starting(warmup) -> Active -> Draining -> Stopped`); the router only
//! targets `Active` instances, and displaced requests are re-routed
//! deterministically.
//!
//! Controllers are registered in the
//! [`PolicyRegistry`](crate::policy::PolicyRegistry) by name, exactly like
//! routing/scheduling/eviction policies and traffic sources. Built-ins:
//!
//! | name              | behavior |
//! |-------------------|----------|
//! | `static`          | no ticks, no actions — byte-identical to the pre-driver run loop |
//! | `queue-threshold` | autoscaler: scale up when the average wait queue per live instance exceeds a threshold, drain back down when it falls below another |
//! | `failure-replay`  | scripted fault injection from `cluster.failures` (fail at an exact time, optionally recover later) |
//! | `chaos`           | seeded random fault injection from `cluster.chaos`: instance crashes, correlated zone outages (optionally partitioning the zone off the fabric), stragglers, link degradation — each with a lognormal MTTR recovery |
//!
//! Determinism contract: controllers see only the [`ClusterView`] and the
//! tick time, ticks land on a fixed grid in *simulated* time, and actions
//! are applied in returned order — so a controlled simulation is exactly as
//! reproducible as a static one, at any sweep worker count.

use crate::config::{ChaosConfig, ClusterConfig, Role};
use crate::memory::CacheStats;
use crate::sim::{Nanos, MILLI};
use crate::util::json::Value;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Lifecycle
// ---------------------------------------------------------------------------

/// Lifecycle state of a serving instance in a dynamic fleet.
///
/// `Starting -> Active -> Draining -> Stopped`, with `Stopped -> Starting`
/// on recovery. Only `Active` instances are router targets; `Draining`
/// instances finish their running batch but admit nothing new; `Stopped`
/// instances hold no requests and report no load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    /// Warming up (model load, KV pool init); becomes `Active` at `until`.
    Starting { until: Nanos },
    /// Serving normally; the only state the router dispatches to.
    Active,
    /// Finishing its running batch; waiting requests were re-routed.
    Draining,
    /// Out of the fleet (drained to empty, failed, or scaled down).
    Stopped,
}

impl Lifecycle {
    pub fn is_active(self) -> bool {
        matches!(self, Lifecycle::Active)
    }

    pub fn is_stopped(self) -> bool {
        matches!(self, Lifecycle::Stopped)
    }

    /// Whether the instance may run engine steps (`Active` or `Draining`).
    pub fn can_run(self) -> bool {
        matches!(self, Lifecycle::Active | Lifecycle::Draining)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Lifecycle::Starting { .. } => "starting",
            Lifecycle::Active => "active",
            Lifecycle::Draining => "draining",
            Lifecycle::Stopped => "stopped",
        }
    }
}

// ---------------------------------------------------------------------------
// Cluster view
// ---------------------------------------------------------------------------

/// Controller-visible snapshot of one instance.
#[derive(Debug, Clone)]
pub struct InstanceSnapshot {
    pub id: usize,
    pub name: String,
    pub hardware: String,
    pub role: Role,
    /// Failure domain (rack/zone) label; chaos faults correlate within it.
    pub zone: String,
    pub lifecycle: Lifecycle,
    /// Step-latency multiplier currently applied (1.0 = healthy,
    /// > 1.0 = straggling under [`ClusterAction::SetPerfScale`]).
    pub perf_scale: f64,
    /// Requests waiting for admission.
    pub waiting: usize,
    /// Sequences in the running batch.
    pub running: usize,
    /// Whether an engine step is in flight.
    pub busy: bool,
    /// KV pool utilization in [0, 1].
    pub kv_utilization: f64,
    /// Current continuous-batching sequence cap (`SetBatchCap` target).
    pub max_batch_seqs: usize,
    /// Prefix-cache stats, if the instance has a cache attached.
    pub cache: Option<CacheStats>,
}

/// Read-only cluster snapshot handed to controllers between steps.
#[derive(Debug, Clone)]
pub struct ClusterView {
    /// Simulated time of the snapshot.
    pub now: Nanos,
    /// Every instance ever created, indexed by id (including `Stopped`).
    pub instances: Vec<InstanceSnapshot>,
    /// Requests arrived but not yet finished.
    pub in_flight: usize,
    /// Requests finished so far.
    pub finished: usize,
    /// Requests arrived so far.
    pub arrivals: usize,
    /// SLO attainment over finished requests so far (1.0 when none).
    pub slo_attainment: f64,
}

impl ClusterView {
    /// Instances currently `Active`.
    pub fn active(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| i.lifecycle.is_active())
            .count()
    }

    /// Instances that are (or are about to be) serving capacity:
    /// `Active` + `Starting`.
    pub fn live(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| {
                matches!(i.lifecycle, Lifecycle::Active | Lifecycle::Starting { .. })
            })
            .count()
    }

    /// Total waiting requests across non-stopped instances.
    pub fn total_waiting(&self) -> usize {
        self.instances
            .iter()
            .filter(|i| !i.lifecycle.is_stopped())
            .map(|i| i.waiting)
            .sum()
    }

    /// Instance ids in `zone`, ascending (stopped instances included — a
    /// domain outage hits whatever is racked there, and recovery needs the
    /// full member list).
    pub fn zone_members(&self, zone: &str) -> Vec<usize> {
        self.instances
            .iter()
            .filter(|i| i.zone == zone)
            .map(|i| i.id)
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Actions + timeline
// ---------------------------------------------------------------------------

/// A typed fleet mutation returned by a controller tick. Actions referring
/// to unknown or wrong-state instances are logged and skipped — a
/// controller bug must not crash the simulation.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterAction {
    /// Add an instance. The new instance clones the config of the first
    /// existing instance with the same role (hardware overridable) and
    /// warms up for `cluster.warmup_ms` before joining the router's
    /// candidate set.
    ScaleUp {
        /// Hardware-registry name; `None` keeps the template's hardware.
        hardware: Option<String>,
        role: Role,
    },
    /// Gracefully remove an instance: re-route its waiting requests,
    /// finish the running batch, then stop.
    ScaleDown { instance: usize },
    /// Same mechanics as [`ScaleDown`](ClusterAction::ScaleDown), recorded
    /// separately in the timeline (operational drain, not capacity change).
    Drain { instance: usize },
    /// Hard failure at absolute time `at` (>= now; past times apply
    /// immediately): all resident requests are lost and re-routed
    /// recompute-style, the instance goes `Stopped`.
    Fail { instance: usize, at: Nanos },
    /// Bring a `Stopped` instance back: it warms up for
    /// `cluster.warmup_ms`, then rejoins as `Active`.
    Recover { instance: usize },
    /// Retune an instance's continuous-batching sequence cap.
    SetBatchCap { instance: usize, max_seqs: usize },
    /// Correlated failure domain outage: every instance whose
    /// [`zone`](InstanceSnapshot::zone) matches fails at absolute time
    /// `at` (same mechanics as [`Fail`](ClusterAction::Fail), per member).
    FailDomain { zone: String, at: Nanos },
    /// Scale the inter-instance fabric bandwidth on every link touching
    /// `instance` (absolute multiplier; `1.0` restores the link).
    DegradeLink { instance: usize, scale: f64 },
    /// Cut every inter-instance fabric link touching instances in `zone`:
    /// cross-zone KV handoffs re-route or park until the fabric heals.
    /// Instances keep serving what they already hold.
    PartitionDomain { zone: String },
    /// Heal the inter-instance fabric completely: all degraded links back
    /// to full bandwidth, all partitions removed, routes byte-identical to
    /// the pristine topology.
    RestoreFabric,
    /// Straggler injection: multiply `instance`'s step latencies by
    /// `scale` (>= 1; `1.0` restores full speed). Applied where step
    /// durations are priced, so schedulers/routers see the slowdown.
    SetPerfScale { instance: usize, scale: f64 },
}

impl ClusterAction {
    /// Timeline kind tag for this action.
    pub fn kind(&self) -> &'static str {
        match self {
            ClusterAction::ScaleUp { .. } => "scale-up",
            ClusterAction::ScaleDown { .. } => "scale-down",
            ClusterAction::Drain { .. } => "drain",
            ClusterAction::Fail { .. } => "fail",
            ClusterAction::Recover { .. } => "recover",
            ClusterAction::SetBatchCap { .. } => "set-batch-cap",
            ClusterAction::FailDomain { .. } => "fail-domain",
            ClusterAction::DegradeLink { .. } => "degrade-link",
            ClusterAction::PartitionDomain { .. } => "partition",
            ClusterAction::RestoreFabric => "restore-fabric",
            ClusterAction::SetPerfScale { .. } => "perf-scale",
        }
    }
}

/// One entry of the controller timeline threaded into
/// [`Report`](crate::metrics::Report): an applied action, a lifecycle
/// transition, or a periodic fleet-size sample.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEntry {
    pub at: Nanos,
    /// `"sample"`, an action kind ([`ClusterAction::kind`]), or a
    /// transition tag (`"ready"`, `"drained"`).
    pub kind: String,
    /// Target instance, if the entry concerns one.
    pub instance: Option<usize>,
    /// `Active` instance count after the entry took effect.
    pub active: usize,
    /// Human-readable detail (hardware name, thresholds, cap values, ...).
    pub detail: String,
}

impl TimelineEntry {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("at_ns", Value::int(self.at as i64)),
            ("kind", Value::str(self.kind.clone())),
            (
                "instance",
                match self.instance {
                    Some(i) => Value::int(i as i64),
                    None => Value::Null,
                },
            ),
            ("active", Value::int(self.active as i64)),
            ("detail", Value::str(self.detail.clone())),
        ])
    }
}

// ---------------------------------------------------------------------------
// The controller trait
// ---------------------------------------------------------------------------

/// A cluster controller: the fourth registered plugin axis.
///
/// Implementations are `Send` and object-safe, registered by name in the
/// [`PolicyRegistry`](crate::policy::PolicyRegistry)
/// (see [`register_cluster_controller`](crate::policy::register_cluster_controller)),
/// and resolved once when a simulation is built from
/// `cluster.controller` in the config.
///
/// Determinism contract: `on_tick` must depend only on its arguments and
/// the controller's own state (which in turn was built from the config and
/// earlier ticks). Break ties on instance id.
pub trait ClusterController: Send {
    /// Registry/report name of this controller.
    fn name(&self) -> &str;

    /// Whether the driver schedules periodic `ControllerTick` events for
    /// this controller. `false` (the `static` built-in) keeps the event
    /// stream — and therefore every report — byte-identical to a run
    /// without any controller.
    fn wants_ticks(&self) -> bool {
        true
    }

    /// Invoked on each tick with a read-only cluster snapshot; returns the
    /// actions to apply, in order.
    fn on_tick(&mut self, now: Nanos, view: &ClusterView) -> Vec<ClusterAction>;

    /// Whether the controller still intends future actions. Keeps the tick
    /// train alive when the event queue is otherwise drained (e.g. a
    /// scripted recovery after the last failure emptied the fleet).
    fn has_pending(&self, now: Nanos) -> bool {
        let _ = now;
        false
    }
}

// ---------------------------------------------------------------------------
// Built-in: static
// ---------------------------------------------------------------------------

/// Today's behavior: a frozen fleet. No ticks are scheduled, so the event
/// stream — and every report — is byte-identical to the pre-driver loop.
#[derive(Debug, Default)]
pub struct StaticController;

impl ClusterController for StaticController {
    fn name(&self) -> &str {
        "static"
    }

    fn wants_ticks(&self) -> bool {
        false
    }

    fn on_tick(&mut self, _now: Nanos, _view: &ClusterView) -> Vec<ClusterAction> {
        vec![]
    }
}

// ---------------------------------------------------------------------------
// Built-in: queue-threshold autoscaler
// ---------------------------------------------------------------------------

/// Reactive autoscaler on wait-queue pressure: scale up (cloning the first
/// `Unified`-role instance) when the average waiting count per live
/// instance exceeds `scale_up_queue`, drain the highest-id active instance
/// when it falls below `scale_down_queue`. A cooldown of
/// [`QueueThreshold::COOLDOWN_TICKS`] ticks between actions damps
/// oscillation, and the fleet stays within
/// `[min_instances, max_instances]`.
#[derive(Debug)]
pub struct QueueThreshold {
    scale_up_queue: f64,
    scale_down_queue: f64,
    min_instances: usize,
    max_instances: usize,
    ticks_since_action: u32,
}

impl QueueThreshold {
    /// Ticks that must pass after an action before the next one.
    pub const COOLDOWN_TICKS: u32 = 2;

    pub fn from_config(cfg: &ClusterConfig) -> QueueThreshold {
        QueueThreshold {
            scale_up_queue: cfg.scale_up_queue,
            scale_down_queue: cfg.scale_down_queue,
            min_instances: cfg.min_instances,
            max_instances: cfg.max_instances,
            ticks_since_action: Self::COOLDOWN_TICKS,
        }
    }
}

impl ClusterController for QueueThreshold {
    fn name(&self) -> &str {
        "queue-threshold"
    }

    fn on_tick(&mut self, _now: Nanos, view: &ClusterView) -> Vec<ClusterAction> {
        self.ticks_since_action = self.ticks_since_action.saturating_add(1);
        if self.ticks_since_action <= Self::COOLDOWN_TICKS {
            return vec![];
        }
        // One capacity measure for every gate: live() = Active + Starting.
        // The scale-down branch previously compared active() against the
        // floor while the scale-up branch used live(); with the warming
        // guard below the two agree (no Starting instances => live ==
        // active), but mixing measures invited exactly the
        // drain-during-warmup bug the guard exists to prevent — pinned by
        // `queue_threshold_floor_survives_warmup`.
        let capacity = view.live();
        let waiting = view.total_waiting();
        let avg = waiting as f64 / capacity.max(1) as f64;
        let starting = view
            .instances
            .iter()
            .any(|i| matches!(i.lifecycle, Lifecycle::Starting { .. }));

        if avg > self.scale_up_queue && capacity < self.max_instances {
            self.ticks_since_action = 0;
            return vec![ClusterAction::ScaleUp {
                hardware: None,
                role: Role::Unified,
            }];
        }
        // Never drain while capacity is still warming up — the queue dip
        // may just be the burst ending before the new instance arrived.
        if avg < self.scale_down_queue && !starting && capacity > self.min_instances {
            // Highest-id active *Unified* instance: scaled-up instances
            // leave first, the original fleet last (deterministic
            // tie-break by id). Prefill/Decode instances are never
            // victims — draining the only Decode instance of a P/D fleet
            // would strand every subsequent handoff, and this controller
            // only ever adds Unified capacity anyway.
            if let Some(victim) = view
                .instances
                .iter()
                .filter(|i| i.lifecycle.is_active() && i.role == Role::Unified)
                .map(|i| i.id)
                .max()
            {
                self.ticks_since_action = 0;
                return vec![ClusterAction::ScaleDown { instance: victim }];
            }
        }
        vec![]
    }
}

// ---------------------------------------------------------------------------
// Built-in: failure-replay
// ---------------------------------------------------------------------------

/// Scripted fault injection from `cluster.failures`: each entry fails one
/// instance at an exact simulated time and optionally recovers it later.
/// Failures are all emitted on the first tick — which the driver fires at
/// t=0 — carrying their exact `at` times; the coordinator schedules them
/// as events, so every failure lands nanosecond-exact regardless of the
/// tick period. Recoveries are emitted on the first tick at or after
/// their time (tick-quantized: recovery precision, unlike failure
/// precision, is bounded by `cluster.tick_ms`).
#[derive(Debug)]
pub struct FailureReplay {
    /// (instance, fail_at, recover_at)
    script: Vec<(usize, Nanos, Option<Nanos>)>,
    fail_emitted: Vec<bool>,
    recover_emitted: Vec<bool>,
}

impl FailureReplay {
    pub fn from_config(cfg: &ClusterConfig) -> FailureReplay {
        let script: Vec<(usize, Nanos, Option<Nanos>)> = cfg
            .failures
            .iter()
            .map(|f| {
                (
                    f.instance,
                    f.at_ms * MILLI,
                    f.recover_ms.map(|r| r * MILLI),
                )
            })
            .collect();
        let n = script.len();
        FailureReplay {
            script,
            fail_emitted: vec![false; n],
            recover_emitted: vec![false; n],
        }
    }
}

impl ClusterController for FailureReplay {
    fn name(&self) -> &str {
        "failure-replay"
    }

    fn on_tick(&mut self, now: Nanos, _view: &ClusterView) -> Vec<ClusterAction> {
        let mut actions = vec![];
        for (i, &(instance, at, recover)) in self.script.iter().enumerate() {
            if !self.fail_emitted[i] {
                self.fail_emitted[i] = true;
                actions.push(ClusterAction::Fail { instance, at });
            }
            if let Some(r) = recover {
                if !self.recover_emitted[i] && now >= r {
                    self.recover_emitted[i] = true;
                    actions.push(ClusterAction::Recover { instance });
                }
            }
        }
        actions
    }

    fn has_pending(&self, _now: Nanos) -> bool {
        self.fail_emitted.iter().any(|e| !e)
            || self
                .script
                .iter()
                .zip(&self.recover_emitted)
                .any(|((_, _, r), emitted)| r.is_some() && !emitted)
    }
}

// ---------------------------------------------------------------------------
// Built-in: chaos (seeded fault injection)
// ---------------------------------------------------------------------------

/// Seeded random fault injection driven by a [`ChaosConfig`] profile.
///
/// Incidents arrive as a Poisson process (`fault_rate` per simulated
/// second). Each incident picks a uniformly random `Active` victim and
/// manifests — by independent profile-weighted draws — as one of:
///
/// 1. a **correlated zone outage** ([`ClusterAction::FailDomain`] on the
///    victim's zone, optionally also [`ClusterAction::PartitionDomain`]
///    cutting the zone off the inter-instance fabric),
/// 2. a **straggler** ([`ClusterAction::SetPerfScale`] with the profile's
///    multiplier),
/// 3. a **link degradation** ([`ClusterAction::DegradeLink`] on the
///    victim's fabric links), or
/// 4. a plain **instance crash** ([`ClusterAction::Fail`]).
///
/// Every incident schedules its own recovery after a lognormal MTTR
/// (crashes/outages recover via [`ClusterAction::Recover`], stragglers and
/// degraded links via a scale-1.0 counter-action, partitions via
/// [`ClusterAction::RestoreFabric`] — which heals the *whole* fabric, so
/// overlapping link incidents are healed along with it). Crash and outage
/// times are nanosecond-exact (carried in the action's `at`); stragglers,
/// degradations, and recoveries are tick-quantized like every other
/// controller decision.
///
/// Determinism: all randomness flows through one [`Rng`] seeded from
/// `cluster.chaos.seed`, incidents are drawn in tick order, and victims
/// come from the id-ordered [`ClusterView`] — so a profile replays
/// byte-identically at any sweep worker count. An inert profile
/// (`fault_rate == 0`) schedules no ticks at all and is byte-identical to
/// no controller.
#[derive(Debug)]
pub struct ChaosController {
    cfg: ChaosConfig,
    rng: Rng,
    /// Absolute time of the next fault incident; `Nanos::MAX` once the
    /// horizon has passed (or the profile is inert).
    next_fault_at: Nanos,
    /// Scheduled recovery actions `(due, action)`, emitted on the first
    /// tick at or after `due`, in insertion order.
    pending: Vec<(Nanos, ClusterAction)>,
}

impl ChaosController {
    pub fn from_config(cfg: &ClusterConfig) -> ChaosController {
        let chaos = cfg.chaos.clone();
        let mut rng = Rng::new(chaos.seed);
        let next_fault_at = if chaos.enabled() {
            (rng.exp(chaos.fault_rate) * 1e9).round() as Nanos
        } else {
            Nanos::MAX
        };
        ChaosController {
            cfg: chaos,
            rng,
            next_fault_at,
            pending: vec![],
        }
    }

    /// Lognormal MTTR draw in nanoseconds (median `mttr_ms`, >= 1 ms).
    fn draw_mttr(&mut self) -> Nanos {
        let median_ns = self.cfg.mttr_ms as f64 * MILLI as f64;
        let ns = self.rng.lognormal(median_ns.ln(), self.cfg.mttr_sigma);
        (ns.max(MILLI as f64)).round() as Nanos
    }

    /// Advance the incident clock, honoring the injection horizon.
    fn advance(&mut self) {
        let step = (self.rng.exp(self.cfg.fault_rate) * 1e9).round() as Nanos;
        self.next_fault_at = self.next_fault_at.saturating_add(step.max(1));
        let horizon = self.cfg.horizon_ms * MILLI;
        if self.cfg.horizon_ms > 0 && self.next_fault_at > horizon {
            self.next_fault_at = Nanos::MAX;
        }
    }

    /// Manifest one incident at exact time `at`, appending the immediate
    /// actions and scheduling recoveries.
    fn inject(&mut self, at: Nanos, view: &ClusterView, out: &mut Vec<ClusterAction>) {
        let victims: Vec<(usize, String)> = view
            .instances
            .iter()
            .filter(|i| i.lifecycle.is_active())
            .map(|i| (i.id, i.zone.clone()))
            .collect();
        if victims.is_empty() {
            // Nothing to break; the incident clock already advanced.
            return;
        }
        let (victim, zone) =
            victims[self.rng.below(victims.len() as u64) as usize].clone();
        let mttr = self.draw_mttr();
        let recover_at = at.saturating_add(mttr);
        if self.rng.chance(self.cfg.domain_correlation) {
            out.push(ClusterAction::FailDomain {
                zone: zone.clone(),
                at,
            });
            if self.rng.chance(self.cfg.partition_prob) {
                out.push(ClusterAction::PartitionDomain { zone: zone.clone() });
                self.pending.push((recover_at, ClusterAction::RestoreFabric));
            }
            for member in view.zone_members(&zone) {
                self.pending
                    .push((recover_at, ClusterAction::Recover { instance: member }));
            }
        } else if self.rng.chance(self.cfg.straggler_prob) {
            out.push(ClusterAction::SetPerfScale {
                instance: victim,
                scale: self.cfg.straggler_scale,
            });
            self.pending.push((
                recover_at,
                ClusterAction::SetPerfScale {
                    instance: victim,
                    scale: 1.0,
                },
            ));
        } else if self.rng.chance(self.cfg.link_degrade_prob) {
            out.push(ClusterAction::DegradeLink {
                instance: victim,
                scale: self.cfg.link_scale,
            });
            self.pending.push((
                recover_at,
                ClusterAction::DegradeLink {
                    instance: victim,
                    scale: 1.0,
                },
            ));
        } else {
            out.push(ClusterAction::Fail {
                instance: victim,
                at,
            });
            self.pending
                .push((recover_at, ClusterAction::Recover { instance: victim }));
        }
    }
}

impl ClusterController for ChaosController {
    fn name(&self) -> &str {
        "chaos"
    }

    /// An inert profile schedules no ticks: the event stream — and the
    /// report — stays byte-identical to a run without any controller.
    fn wants_ticks(&self) -> bool {
        self.cfg.enabled()
    }

    fn on_tick(&mut self, now: Nanos, view: &ClusterView) -> Vec<ClusterAction> {
        let mut actions = vec![];
        // Due recoveries first (insertion order = schedule order).
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].0 <= now {
                actions.push(self.pending.remove(i).1);
            } else {
                i += 1;
            }
        }
        // Then every incident whose arrival time has come.
        while self.next_fault_at <= now {
            let at = self.next_fault_at;
            self.advance();
            self.inject(at, view, &mut actions);
        }
        actions
    }

    /// Pending recoveries keep the tick train alive; future *incidents* do
    /// not — chaos only injects while the simulation is naturally live.
    fn has_pending(&self, _now: Nanos) -> bool {
        !self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FailureSpec;

    fn snap(id: usize, lifecycle: Lifecycle, waiting: usize) -> InstanceSnapshot {
        InstanceSnapshot {
            id,
            name: format!("inst{id}"),
            hardware: "rtx3090".into(),
            role: Role::Unified,
            zone: "default".into(),
            lifecycle,
            perf_scale: 1.0,
            waiting,
            running: 0,
            busy: false,
            kv_utilization: 0.0,
            max_batch_seqs: 64,
            cache: None,
        }
    }

    fn view(instances: Vec<InstanceSnapshot>) -> ClusterView {
        ClusterView {
            now: 0,
            instances,
            in_flight: 0,
            finished: 0,
            arrivals: 0,
            slo_attainment: 1.0,
        }
    }

    #[test]
    fn static_controller_never_ticks_or_acts() {
        let mut c = StaticController;
        assert_eq!(c.name(), "static");
        assert!(!c.wants_ticks());
        assert!(!c.has_pending(0));
        assert!(c
            .on_tick(0, &view(vec![snap(0, Lifecycle::Active, 100)]))
            .is_empty());
    }

    #[test]
    fn queue_threshold_scales_up_then_down() {
        let cfg = ClusterConfig::default();
        let mut c = QueueThreshold::from_config(&cfg);
        // pressure above the up threshold -> scale up (after warm start)
        let hot = view(vec![snap(0, Lifecycle::Active, 20)]);
        let a = c.on_tick(0, &hot);
        assert_eq!(
            a,
            vec![ClusterAction::ScaleUp {
                hardware: None,
                role: Role::Unified
            }]
        );
        // cooldown: immediate next tick does nothing even under pressure
        assert!(c.on_tick(1, &hot).is_empty());
        assert!(c.on_tick(2, &hot).is_empty());
        // while the new instance warms up, an idle queue does NOT drain
        let warming = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Starting { until: 99 }, 0),
        ]);
        assert!(c.on_tick(3, &warming).is_empty());
        // once active and idle, the highest-id instance drains first
        let idle = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Active, 0),
        ]);
        let a = c.on_tick(4, &idle);
        assert_eq!(a, vec![ClusterAction::ScaleDown { instance: 1 }]);
        // fleet never drains below min_instances
        let single = view(vec![snap(0, Lifecycle::Active, 0)]);
        assert!(c.on_tick(10, &single).is_empty());
        assert!(c.on_tick(11, &single).is_empty());
        assert!(c.on_tick(12, &single).is_empty());
    }

    #[test]
    fn queue_threshold_never_drains_pd_role_instances() {
        let mut c = QueueThreshold::from_config(&ClusterConfig::default());
        // An idle P/D fleet: both instances above min_instances, but
        // neither is Unified — the autoscaler must not touch them (a
        // drained Decode instance would strand every future handoff).
        let mut prefill = snap(0, Lifecycle::Active, 0);
        prefill.role = Role::Prefill;
        let mut decode = snap(1, Lifecycle::Active, 0);
        decode.role = Role::Decode;
        let pd = view(vec![prefill, decode]);
        for t in 0..5 {
            assert!(c.on_tick(t, &pd).is_empty(), "tick {t} acted on P/D");
        }
        // With a Unified instance present, only that one is the victim —
        // never the higher-id Decode instance.
        let mut decode = snap(2, Lifecycle::Active, 0);
        decode.role = Role::Decode;
        let mixed = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Active, 0),
            decode,
        ]);
        let a = c.on_tick(10, &mixed);
        assert_eq!(a, vec![ClusterAction::ScaleDown { instance: 1 }]);
    }

    #[test]
    fn queue_threshold_respects_max_instances() {
        let cfg = ClusterConfig {
            max_instances: 2,
            ..Default::default()
        };
        let mut c = QueueThreshold::from_config(&cfg);
        let hot = view(vec![
            snap(0, Lifecycle::Active, 50),
            snap(1, Lifecycle::Active, 50),
        ]);
        assert!(c.on_tick(0, &hot).is_empty(), "at max: no further scale-up");
        assert!(!c.has_pending(0));
    }

    #[test]
    fn failure_replay_emits_script_exactly_once() {
        let cfg = ClusterConfig {
            failures: vec![
                FailureSpec {
                    instance: 0,
                    at_ms: 5,
                    recover_ms: Some(20),
                },
                FailureSpec {
                    instance: 1,
                    at_ms: 10,
                    recover_ms: None,
                },
            ],
            ..Default::default()
        };
        let mut c = FailureReplay::from_config(&cfg);
        assert!(c.has_pending(0));
        let v = view(vec![snap(0, Lifecycle::Active, 0)]);
        // first tick: both failures emitted with exact times; no recovery yet
        let a = c.on_tick(0, &v);
        assert_eq!(
            a,
            vec![
                ClusterAction::Fail {
                    instance: 0,
                    at: 5 * MILLI
                },
                ClusterAction::Fail {
                    instance: 1,
                    at: 10 * MILLI
                },
            ]
        );
        // recovery pending keeps the tick train alive
        assert!(c.has_pending(6 * MILLI));
        assert!(c.on_tick(10 * MILLI, &v).is_empty());
        // at/after the recover time, exactly one Recover fires
        let a = c.on_tick(20 * MILLI, &v);
        assert_eq!(a, vec![ClusterAction::Recover { instance: 0 }]);
        assert!(!c.has_pending(21 * MILLI));
        assert!(c.on_tick(30 * MILLI, &v).is_empty());
    }

    /// Regression (ISSUE 8): the drain gate compared `active()` against the
    /// floor while the scale-up gate used `live()`. The gates now share one
    /// capacity measure, and this test pins the floor across every warmup
    /// shape — it fails if the measures are re-split or the warming guard
    /// is dropped (either of which lets the fleet drain serving capacity
    /// while the floor is only satisfied by `Starting` instances).
    #[test]
    fn queue_threshold_floor_survives_warmup() {
        let cfg = ClusterConfig {
            min_instances: 2,
            ..Default::default()
        };
        let mut c = QueueThreshold::from_config(&cfg);
        // Floor met only with warming capacity: 1 Active + 1 Starting.
        let warming = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Starting { until: 99 }, 0),
        ]);
        for t in 0..5 {
            assert!(
                c.on_tick(t, &warming).is_empty(),
                "tick {t}: drained while the floor depended on Starting capacity"
            );
        }
        // Excess capacity, but one instance still warming: hold.
        let excess_warming = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Active, 0),
            snap(2, Lifecycle::Starting { until: 99 }, 0),
        ]);
        for t in 5..10 {
            assert!(
                c.on_tick(t, &excess_warming).is_empty(),
                "tick {t}: drained during warmup"
            );
        }
        // Warmup done and capacity above the floor: now it drains.
        let excess = view(vec![
            snap(0, Lifecycle::Active, 0),
            snap(1, Lifecycle::Active, 0),
            snap(2, Lifecycle::Active, 0),
        ]);
        assert_eq!(
            c.on_tick(10, &excess),
            vec![ClusterAction::ScaleDown { instance: 2 }]
        );
    }

    fn chaos_cluster_cfg(profile: &str, seed: u64) -> ClusterConfig {
        let mut chaos = crate::config::ChaosConfig::profile(profile).unwrap();
        chaos.seed = seed;
        ClusterConfig {
            controller: "chaos".into(),
            chaos,
            ..Default::default()
        }
    }

    #[test]
    fn chaos_inert_profile_schedules_nothing() {
        let cfg = chaos_cluster_cfg("none", 7);
        let mut c = ChaosController::from_config(&cfg);
        assert_eq!(c.name(), "chaos");
        assert!(!c.wants_ticks(), "inert profile must not want ticks");
        assert!(!c.has_pending(0));
        let v = view(vec![snap(0, Lifecycle::Active, 5)]);
        assert!(c.on_tick(u64::MAX / 2, &v).is_empty());
    }

    #[test]
    fn chaos_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let cfg = chaos_cluster_cfg("heavy", seed);
            let mut c = ChaosController::from_config(&cfg);
            let v = view(
                (0..4)
                    .map(|i| {
                        let mut s = snap(i, Lifecycle::Active, 3);
                        s.zone = ["zone-a", "zone-b"][i % 2].to_string();
                        s
                    })
                    .collect(),
            );
            let mut log = vec![];
            for tick in 0..2000u64 {
                for a in c.on_tick(tick * 10 * MILLI, &v) {
                    log.push(format!("{tick}:{}:{a:?}", a.kind()));
                }
            }
            log
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed must replay identically");
        assert!(!a.is_empty(), "heavy profile over 20s injected nothing");
        assert_ne!(a, run(43), "different seed should diverge");
        // incidents break things and recoveries heal them: over 20s at 2
        // faults/s both sides of the cycle must appear in the log
        let fails = a
            .iter()
            .filter(|l| l.contains(":fail:") || l.contains(":fail-domain:"))
            .count();
        let recovers = a.iter().filter(|l| l.contains(":recover:")).count();
        assert!(recovers > 0 && fails > 0, "log: {} entries", a.len());
    }

    #[test]
    fn chaos_domain_outage_hits_every_zone_member_and_recovers() {
        // domain_correlation = 1 and partition_prob = 1 ("partition"
        // profile): every incident fails the victim's entire zone,
        // partitions it, and later recovers every member + the fabric.
        // A 5 s horizon bounds injection so the late drain tick below only
        // emits recoveries.
        let mut cfg = chaos_cluster_cfg("partition", 1);
        cfg.chaos.horizon_ms = 5_000;
        let mut c = ChaosController::from_config(&cfg);
        let v = view(
            (0..4)
                .map(|i| {
                    let mut s = snap(i, Lifecycle::Active, 0);
                    s.zone = if i < 2 { "za".into() } else { "zb".into() };
                    s
                })
                .collect(),
        );
        // one tick past the horizon: all incidents of the run arrive here
        let actions = c.on_tick(10_000 * MILLI, &v);
        let zone = actions
            .iter()
            .find_map(|a| match a {
                ClusterAction::FailDomain { zone, .. } => Some(zone.clone()),
                _ => None,
            })
            .expect("no FailDomain from a domain_correlation=1 profile in 5s");
        assert!(
            actions
                .iter()
                .any(|a| matches!(a, ClusterAction::PartitionDomain { zone: z } if *z == zone)),
            "partition_prob=1 must partition the failed zone"
        );
        // recoveries pending for every member of the zone + the fabric
        assert!(c.has_pending(0));
        let later = c.on_tick(u64::MAX / 2, &v);
        let recovered: std::collections::BTreeSet<usize> = later
            .iter()
            .filter_map(|a| match a {
                ClusterAction::Recover { instance } => Some(*instance),
                _ => None,
            })
            .collect();
        for member in v.zone_members(&zone) {
            assert!(recovered.contains(&member), "member {member} never recovered");
        }
        assert!(
            later.iter().any(|a| matches!(a, ClusterAction::RestoreFabric)),
            "partition must heal via RestoreFabric"
        );
        assert!(!c.has_pending(u64::MAX / 2), "recoveries must drain");
    }

    #[test]
    fn zone_members_ascending_and_zone_scoped() {
        let mut a = snap(0, Lifecycle::Active, 0);
        a.zone = "za".into();
        let mut b = snap(1, Lifecycle::Stopped, 0);
        b.zone = "zb".into();
        let mut c = snap(2, Lifecycle::Active, 0);
        c.zone = "za".into();
        let v = view(vec![a, b, c]);
        assert_eq!(v.zone_members("za"), vec![0, 2]);
        // stopped members are still part of their domain
        assert_eq!(v.zone_members("zb"), vec![1]);
        assert!(v.zone_members("zz").is_empty());
    }

    #[test]
    fn lifecycle_predicates() {
        assert!(Lifecycle::Active.is_active());
        assert!(Lifecycle::Active.can_run());
        assert!(Lifecycle::Draining.can_run());
        assert!(!Lifecycle::Draining.is_active());
        assert!(!Lifecycle::Starting { until: 5 }.can_run());
        assert!(Lifecycle::Stopped.is_stopped());
        assert_eq!(Lifecycle::Starting { until: 5 }.as_str(), "starting");
        assert_eq!(Lifecycle::Stopped.as_str(), "stopped");
    }

    #[test]
    fn view_aggregates() {
        let v = view(vec![
            snap(0, Lifecycle::Active, 3),
            snap(1, Lifecycle::Starting { until: 9 }, 2),
            snap(2, Lifecycle::Draining, 1),
            snap(3, Lifecycle::Stopped, 7),
        ]);
        assert_eq!(v.active(), 1);
        assert_eq!(v.live(), 2);
        // stopped instances contribute no waiting
        assert_eq!(v.total_waiting(), 6);
    }

    #[test]
    fn timeline_entry_serializes() {
        let e = TimelineEntry {
            at: 42,
            kind: "scale-up".into(),
            instance: Some(3),
            active: 2,
            detail: "hw=rtx3090".into(),
        };
        let j = e.to_json();
        assert_eq!(j.get("at_ns").as_i64(), Some(42));
        assert_eq!(j.get("kind").as_str(), Some("scale-up"));
        assert_eq!(j.get("instance").as_i64(), Some(3));
        let none = TimelineEntry {
            instance: None,
            ..e
        };
        assert!(none.to_json().get("instance").is_null());
    }

    #[test]
    fn action_kinds_are_stable() {
        assert_eq!(
            ClusterAction::ScaleUp {
                hardware: None,
                role: Role::Unified
            }
            .kind(),
            "scale-up"
        );
        assert_eq!(ClusterAction::Drain { instance: 0 }.kind(), "drain");
        assert_eq!(
            ClusterAction::Fail {
                instance: 0,
                at: 0
            }
            .kind(),
            "fail"
        );
        assert_eq!(ClusterAction::Recover { instance: 0 }.kind(), "recover");
        assert_eq!(
            ClusterAction::SetBatchCap {
                instance: 0,
                max_seqs: 8
            }
            .kind(),
            "set-batch-cap"
        );
        assert_eq!(
            ClusterAction::FailDomain {
                zone: "za".into(),
                at: 0
            }
            .kind(),
            "fail-domain"
        );
        assert_eq!(
            ClusterAction::DegradeLink {
                instance: 0,
                scale: 0.5
            }
            .kind(),
            "degrade-link"
        );
        assert_eq!(
            ClusterAction::PartitionDomain { zone: "za".into() }.kind(),
            "partition"
        );
        assert_eq!(ClusterAction::RestoreFabric.kind(), "restore-fabric");
        assert_eq!(
            ClusterAction::SetPerfScale {
                instance: 0,
                scale: 2.0
            }
            .kind(),
            "perf-scale"
        );
    }
}
