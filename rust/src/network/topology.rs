//! Link-graph topologies: fully-connected, ring, star (switch), and
//! hierarchical (intra-node fast + inter-node slow), with precomputed
//! shortest routes.

use crate::sim::Nanos;

/// Index into [`Topology::links`].
pub type LinkId = usize;

/// One directed link.
#[derive(Debug, Clone, PartialEq)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
    /// Bytes per second.
    pub bandwidth: f64,
    /// Base propagation latency, ns.
    pub latency: Nanos,
}

/// A device interconnect graph with precomputed BFS routes.
#[derive(Debug, Clone)]
pub struct Topology {
    num_devices: usize,
    links: Vec<Link>,
    /// `routes[src][dst]` = link ids along the path.
    routes: Vec<Vec<Vec<LinkId>>>,
    pub name: String,
}

impl Topology {
    /// Build from an explicit link list.
    pub fn new(name: &str, num_devices: usize, links: Vec<Link>) -> Self {
        let routes = Self::compute_routes(num_devices, &links);
        Topology {
            num_devices,
            links,
            routes,
            name: name.to_string(),
        }
    }

    /// Every device pair directly connected (NVLink-style).
    pub fn fully_connected(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let mut links = vec![];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    links.push(Link {
                        src: i,
                        dst: j,
                        bandwidth,
                        latency,
                    });
                }
            }
        }
        Topology::new("fully-connected", n, links)
    }

    /// Bidirectional ring (TPU-pod-slice-style).
    pub fn ring(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let mut links = vec![];
        for i in 0..n {
            let next = (i + 1) % n;
            links.push(Link {
                src: i,
                dst: next,
                bandwidth,
                latency,
            });
            links.push(Link {
                src: next,
                dst: i,
                bandwidth,
                latency,
            });
        }
        Topology::new("ring", n, links)
    }

    /// Star through a switch: device i <-> switch (node index n).
    /// The switch is modeled as an extra node with 2n links.
    pub fn switched(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let switch = n;
        let mut links = vec![];
        for i in 0..n {
            links.push(Link {
                src: i,
                dst: switch,
                bandwidth,
                latency,
            });
            links.push(Link {
                src: switch,
                dst: i,
                bandwidth,
                latency,
            });
        }
        Topology::new("switched", n + 1, links)
    }

    /// Two-level hierarchy: `nodes` groups of `per_node` devices; fast
    /// intra-node links (fully connected), slow inter-node links between
    /// node leaders (ring).
    pub fn hierarchical(
        nodes: usize,
        per_node: usize,
        intra_bw: f64,
        intra_lat: Nanos,
        inter_bw: f64,
        inter_lat: Nanos,
    ) -> Topology {
        let n = nodes * per_node;
        let mut links = vec![];
        for g in 0..nodes {
            let base = g * per_node;
            for i in 0..per_node {
                for j in 0..per_node {
                    if i != j {
                        links.push(Link {
                            src: base + i,
                            dst: base + j,
                            bandwidth: intra_bw,
                            latency: intra_lat,
                        });
                    }
                }
            }
        }
        for g in 0..nodes {
            let next = ((g + 1) % nodes) * per_node;
            let cur = g * per_node;
            if nodes > 1 {
                links.push(Link {
                    src: cur,
                    dst: next,
                    bandwidth: inter_bw,
                    latency: inter_lat,
                });
                links.push(Link {
                    src: next,
                    dst: cur,
                    bandwidth: inter_bw,
                    latency: inter_lat,
                });
            }
        }
        Topology::new("hierarchical", n, links)
    }

    pub fn num_devices(&self) -> usize {
        self.num_devices
    }
    pub fn num_links(&self) -> usize {
        self.links.len()
    }
    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Link ids along the (precomputed BFS-shortest) route src -> dst.
    /// Panics if unreachable — topologies are validated at construction.
    pub fn route(&self, src: usize, dst: usize) -> Vec<LinkId> {
        self.routes[src][dst].clone()
    }

    pub fn is_connected(&self) -> bool {
        (0..self.num_devices).all(|s| {
            (0..self.num_devices).all(|d| s == d || !self.routes[s][d].is_empty())
        })
    }

    fn compute_routes(n: usize, links: &[Link]) -> Vec<Vec<Vec<LinkId>>> {
        // adjacency: node -> (neighbor, link id)
        let mut adj: Vec<Vec<(usize, LinkId)>> = vec![vec![]; n];
        for (id, l) in links.iter().enumerate() {
            adj[l.src].push((l.dst, id));
        }
        let mut routes = vec![vec![vec![]; n]; n];
        for src in 0..n {
            // BFS
            let mut prev: Vec<Option<(usize, LinkId)>> = vec![None; n];
            let mut visited = vec![false; n];
            let mut queue = std::collections::VecDeque::new();
            visited[src] = true;
            queue.push_back(src);
            while let Some(u) = queue.pop_front() {
                for &(v, link) in &adj[u] {
                    if !visited[v] {
                        visited[v] = true;
                        prev[v] = Some((u, link));
                        queue.push_back(v);
                    }
                }
            }
            for dst in 0..n {
                if dst == src || !visited[dst] {
                    continue;
                }
                let mut path = vec![];
                let mut cur = dst;
                while let Some((p, link)) = prev[cur] {
                    path.push(link);
                    cur = p;
                }
                path.reverse();
                routes[src][dst] = path;
            }
        }
        routes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_single_hop() {
        let t = Topology::fully_connected(4, 1e9, 100);
        assert!(t.is_connected());
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(t.route(i, j).len(), 1);
                }
            }
        }
        assert_eq!(t.num_links(), 12);
    }

    #[test]
    fn ring_shortest_path() {
        let t = Topology::ring(6, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 1);
        assert_eq!(t.route(0, 3).len(), 3);
        // BFS finds the short way around
        assert_eq!(t.route(0, 5).len(), 1);
    }

    #[test]
    fn switched_two_hops() {
        let t = Topology::switched(4, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 2); // via switch
        assert_eq!(t.num_devices(), 5);
    }

    #[test]
    fn hierarchical_intra_vs_inter() {
        let t = Topology::hierarchical(2, 2, 100e9, 100, 10e9, 1000);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 1); // intra-node
        assert!(t.route(1, 3).len() >= 2); // crosses node boundary
    }

    #[test]
    fn single_device_trivial() {
        let t = Topology::fully_connected(1, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.num_links(), 0);
    }
}
