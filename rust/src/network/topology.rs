//! Link-graph topologies: fully-connected, ring, star (switch), and
//! hierarchical (intra-node fast + inter-node slow), with precomputed
//! shortest routes.
//!
//! Topologies are mutable under the chaos subsystem (DESIGN.md §12):
//! links can be **degraded** ([`Topology::set_link_scale`] — a bandwidth
//! multiplier that leaves routing untouched) or **removed/restored**
//! ([`Topology::remove_link`] / [`Topology::restore_link`] /
//! [`Topology::isolate_device`] / [`Topology::restore_all`]), with routes
//! recomputed deterministically after every connectivity change. The base
//! link list is never mutated, so a full restore reproduces the original
//! routes byte-identically.

use crate::sim::Nanos;

/// Index into [`Topology::links`].
pub type LinkId = usize;

/// One directed link.
#[derive(Debug, Clone, PartialEq)]
pub struct Link {
    pub src: usize,
    pub dst: usize,
    /// Bytes per second.
    pub bandwidth: f64,
    /// Base propagation latency, ns.
    pub latency: Nanos,
}

/// A device interconnect graph with precomputed BFS routes.
#[derive(Debug, Clone)]
pub struct Topology {
    num_devices: usize,
    /// Base (pristine) links. Never mutated — degradation and partition
    /// state live in `scale` / `removed`, so `restore_all` is exact.
    links: Vec<Link>,
    /// Per-link bandwidth multiplier (1.0 = healthy).
    scale: Vec<f64>,
    /// Per-link partition flag; removed links drop out of routing and
    /// collective pricing but keep their [`LinkId`] stable.
    removed: Vec<bool>,
    /// `routes[src][dst]` = link ids along the path.
    routes: Vec<Vec<Vec<LinkId>>>,
    pub name: String,
}

impl Topology {
    /// Build from an explicit link list.
    pub fn new(name: &str, num_devices: usize, links: Vec<Link>) -> Self {
        let scale = vec![1.0; links.len()];
        let removed = vec![false; links.len()];
        let mut t = Topology {
            num_devices,
            links,
            scale,
            removed,
            routes: vec![],
            name: name.to_string(),
        };
        t.recompute_routes();
        t
    }

    /// Every device pair directly connected (NVLink-style).
    pub fn fully_connected(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let mut links = vec![];
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    links.push(Link {
                        src: i,
                        dst: j,
                        bandwidth,
                        latency,
                    });
                }
            }
        }
        Topology::new("fully-connected", n, links)
    }

    /// Bidirectional ring (TPU-pod-slice-style).
    ///
    /// Each undirected ring edge contributes exactly one link per
    /// direction: `n == 1` has no edges (a self-loop carries no traffic)
    /// and `n == 2` has a single `0 <-> 1` pair — wrapping around the
    /// two-node ring would emit the same directed links twice, presenting
    /// double-counted parallel paths to collective pricing.
    pub fn ring(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let mut links = vec![];
        for i in 0..n {
            let next = (i + 1) % n;
            if next == i || (n == 2 && i == 1) {
                continue;
            }
            links.push(Link {
                src: i,
                dst: next,
                bandwidth,
                latency,
            });
            links.push(Link {
                src: next,
                dst: i,
                bandwidth,
                latency,
            });
        }
        Topology::new("ring", n, links)
    }

    /// Star through a switch: device i <-> switch (node index n).
    /// The switch is modeled as an extra node with 2n links.
    pub fn switched(n: usize, bandwidth: f64, latency: Nanos) -> Topology {
        let switch = n;
        let mut links = vec![];
        for i in 0..n {
            links.push(Link {
                src: i,
                dst: switch,
                bandwidth,
                latency,
            });
            links.push(Link {
                src: switch,
                dst: i,
                bandwidth,
                latency,
            });
        }
        Topology::new("switched", n + 1, links)
    }

    /// Two-level hierarchy: `nodes` groups of `per_node` devices; fast
    /// intra-node links (fully connected), slow inter-node links between
    /// node leaders (ring).
    pub fn hierarchical(
        nodes: usize,
        per_node: usize,
        intra_bw: f64,
        intra_lat: Nanos,
        inter_bw: f64,
        inter_lat: Nanos,
    ) -> Topology {
        let n = nodes * per_node;
        let mut links = vec![];
        for g in 0..nodes {
            let base = g * per_node;
            for i in 0..per_node {
                for j in 0..per_node {
                    if i != j {
                        links.push(Link {
                            src: base + i,
                            dst: base + j,
                            bandwidth: intra_bw,
                            latency: intra_lat,
                        });
                    }
                }
            }
        }
        for g in 0..nodes {
            let next = ((g + 1) % nodes) * per_node;
            let cur = g * per_node;
            if nodes > 1 {
                links.push(Link {
                    src: cur,
                    dst: next,
                    bandwidth: inter_bw,
                    latency: inter_lat,
                });
                links.push(Link {
                    src: next,
                    dst: cur,
                    bandwidth: inter_bw,
                    latency: inter_lat,
                });
            }
        }
        Topology::new("hierarchical", n, links)
    }

    pub fn num_devices(&self) -> usize {
        self.num_devices
    }
    pub fn num_links(&self) -> usize {
        self.links.len()
    }
    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Effective bandwidth of link `id` with any degradation applied.
    pub fn link_bandwidth(&self, id: LinkId) -> f64 {
        self.links[id].bandwidth * self.scale[id]
    }

    /// Whether link `id` is currently partitioned away.
    pub fn link_removed(&self, id: LinkId) -> bool {
        self.removed[id]
    }

    /// Link ids along the (precomputed BFS-shortest) route src -> dst.
    /// Empty for `src == dst` — and for pairs made unreachable by a
    /// partition ([`Self::reachable`] disambiguates; transfer pricing must
    /// treat unreachable pairs as blocked, not free).
    pub fn route(&self, src: usize, dst: usize) -> Vec<LinkId> {
        self.routes[src][dst].clone()
    }

    /// Whether `dst` is currently reachable from `src` (trivially true for
    /// `src == dst`).
    pub fn reachable(&self, src: usize, dst: usize) -> bool {
        src == dst || !self.routes[src][dst].is_empty()
    }

    pub fn is_connected(&self) -> bool {
        (0..self.num_devices).all(|s| {
            (0..self.num_devices).all(|d| s == d || !self.routes[s][d].is_empty())
        })
    }

    // ---- fault injection (DESIGN.md §12) -------------------------------

    /// Degrade (or restore, with `scale = 1.0`) the directed link
    /// `src -> dst` to `scale` x its base bandwidth. The scale is
    /// **absolute**, not compounding, so repeated degradations are
    /// idempotent and `1.0` is always a full repair. Returns the number of
    /// links matched (0 when no such link exists). Routes are hop-count
    /// shortest paths, so scaling never re-routes.
    pub fn set_link_scale(&mut self, src: usize, dst: usize, scale: f64) -> usize {
        let scale = scale.max(1e-12);
        let mut n = 0;
        for (id, l) in self.links.iter().enumerate() {
            if l.src == src && l.dst == dst {
                self.scale[id] = scale;
                n += 1;
            }
        }
        n
    }

    /// Degrade every link incident to `dev` (its NIC slows down). Returns
    /// the number of links touched.
    pub fn scale_device(&mut self, dev: usize, scale: f64) -> usize {
        let scale = scale.max(1e-12);
        let mut n = 0;
        for (id, l) in self.links.iter().enumerate() {
            if l.src == dev || l.dst == dev {
                self.scale[id] = scale;
                n += 1;
            }
        }
        n
    }

    /// Remove the directed link `src -> dst` from routing (partition).
    /// Link ids stay stable; routes are recomputed deterministically.
    pub fn remove_link(&mut self, src: usize, dst: usize) -> usize {
        let n = self.mark_links(src, dst, true);
        if n > 0 {
            self.recompute_routes();
        }
        n
    }

    /// Restore a previously removed directed link and recompute routes.
    pub fn restore_link(&mut self, src: usize, dst: usize) -> usize {
        let n = self.mark_links(src, dst, false);
        if n > 0 {
            self.recompute_routes();
        }
        n
    }

    /// Partition `dev` off the fabric: remove every incident link.
    /// Returns the number of links removed.
    pub fn isolate_device(&mut self, dev: usize) -> usize {
        let mut n = 0;
        for (id, l) in self.links.iter().enumerate() {
            if (l.src == dev || l.dst == dev) && !self.removed[id] {
                self.removed[id] = true;
                n += 1;
            }
        }
        if n > 0 {
            self.recompute_routes();
        }
        n
    }

    /// Undo [`Self::isolate_device`] for `dev`.
    pub fn restore_device(&mut self, dev: usize) -> usize {
        let mut n = 0;
        for (id, l) in self.links.iter().enumerate() {
            if (l.src == dev || l.dst == dev) && self.removed[id] {
                self.removed[id] = false;
                n += 1;
            }
        }
        if n > 0 {
            self.recompute_routes();
        }
        n
    }

    /// Clear every degradation and partition. Because the base link list
    /// is never mutated and route computation is deterministic, the
    /// restored routes are byte-identical to the original ones.
    pub fn restore_all(&mut self) {
        self.scale.iter_mut().for_each(|s| *s = 1.0);
        self.removed.iter_mut().for_each(|r| *r = false);
        self.recompute_routes();
    }

    fn mark_links(&mut self, src: usize, dst: usize, removed: bool) -> usize {
        let mut n = 0;
        for (id, l) in self.links.iter().enumerate() {
            if l.src == src && l.dst == dst && self.removed[id] != removed {
                self.removed[id] = removed;
                n += 1;
            }
        }
        n
    }

    /// Deterministic per-source BFS over the live (non-removed) links.
    /// Adjacency is built in link-id order and the queue is FIFO, so equal
    /// inputs always produce identical routes.
    fn recompute_routes(&mut self) {
        let n = self.num_devices;
        let mut adj: Vec<Vec<(usize, LinkId)>> = vec![vec![]; n];
        for (id, l) in self.links.iter().enumerate() {
            if !self.removed[id] {
                adj[l.src].push((l.dst, id));
            }
        }
        let mut routes = vec![vec![vec![]; n]; n];
        for src in 0..n {
            // BFS
            let mut prev: Vec<Option<(usize, LinkId)>> = vec![None; n];
            let mut visited = vec![false; n];
            let mut queue = std::collections::VecDeque::new();
            visited[src] = true;
            queue.push_back(src);
            while let Some(u) = queue.pop_front() {
                for &(v, link) in &adj[u] {
                    if !visited[v] {
                        visited[v] = true;
                        prev[v] = Some((u, link));
                        queue.push_back(v);
                    }
                }
            }
            for dst in 0..n {
                if dst == src || !visited[dst] {
                    continue;
                }
                let mut path = vec![];
                let mut cur = dst;
                while let Some((p, link)) = prev[cur] {
                    path.push(link);
                    cur = p;
                }
                path.reverse();
                routes[src][dst] = path;
            }
        }
        self.routes = routes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fully_connected_single_hop() {
        let t = Topology::fully_connected(4, 1e9, 100);
        assert!(t.is_connected());
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(t.route(i, j).len(), 1);
                }
            }
        }
        assert_eq!(t.num_links(), 12);
    }

    #[test]
    fn ring_shortest_path() {
        let t = Topology::ring(6, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 1);
        assert_eq!(t.route(0, 3).len(), 3);
        // BFS finds the short way around
        assert_eq!(t.route(0, 5).len(), 1);
    }

    #[test]
    fn switched_two_hops() {
        let t = Topology::switched(4, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 2); // via switch
        assert_eq!(t.num_devices(), 5);
    }

    #[test]
    fn hierarchical_intra_vs_inter() {
        let t = Topology::hierarchical(2, 2, 100e9, 100, 10e9, 1000);
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 1); // intra-node
        assert!(t.route(1, 3).len() >= 2); // crosses node boundary
    }

    #[test]
    fn single_device_trivial() {
        let t = Topology::fully_connected(1, 1e9, 100);
        assert!(t.is_connected());
        assert_eq!(t.num_links(), 0);
    }

    /// Regression (ISSUE 8): the ring builder used to emit both directions
    /// for every `i`, so `n == 2` produced duplicate `0->1`/`1->0` links
    /// (double-counted parallel paths) and `n == 1` two self-loops.
    #[test]
    fn ring_small_n_has_no_duplicate_or_self_loop_links() {
        // n = 1: no links at all — a self-loop carries no traffic.
        let t = Topology::ring(1, 1e9, 100);
        assert_eq!(t.num_links(), 0, "n=1 ring must not emit self-loops");
        assert!(t.is_connected());
        assert!(t.route(0, 0).is_empty());

        // n = 2: exactly one link per direction, and they route directly.
        let t = Topology::ring(2, 1e9, 100);
        assert_eq!(t.num_links(), 2, "n=2 ring must not duplicate its edge");
        let pairs: std::collections::BTreeSet<(usize, usize)> =
            t.links().iter().map(|l| (l.src, l.dst)).collect();
        assert_eq!(pairs.len(), 2, "duplicate directed links: {:?}", t.links());
        assert!(pairs.contains(&(0, 1)) && pairs.contains(&(1, 0)));
        assert!(t.is_connected());
        assert_eq!(t.route(0, 1).len(), 1);
        assert_eq!(t.route(1, 0).len(), 1);

        // n = 3: one link per direction per edge — 6 distinct links.
        let t = Topology::ring(3, 1e9, 100);
        assert_eq!(t.num_links(), 6);
        let pairs: std::collections::BTreeSet<(usize, usize)> =
            t.links().iter().map(|l| (l.src, l.dst)).collect();
        assert_eq!(pairs.len(), 6, "duplicate directed links: {:?}", t.links());
        assert!(t.is_connected());
        for (i, j) in [(0, 1), (1, 2), (2, 0)] {
            assert_eq!(t.route(i, j).len(), 1);
            assert_eq!(t.route(j, i).len(), 1);
        }
    }

    /// Full route matrix, for byte-exact route comparisons.
    fn route_matrix(t: &Topology) -> Vec<Vec<Vec<usize>>> {
        (0..t.num_devices())
            .map(|s| (0..t.num_devices()).map(|d| t.route(s, d)).collect())
            .collect()
    }

    #[test]
    fn degrade_scales_bandwidth_without_rerouting() {
        let mut t = Topology::switched(4, 1e9, 100);
        let before = route_matrix(&t);
        assert_eq!(t.set_link_scale(0, 4, 0.25), 1, "0 -> switch exists");
        assert!((t.link_bandwidth(0) - 0.25e9).abs() < 1.0);
        // absolute, not compounding
        assert_eq!(t.set_link_scale(0, 4, 0.25), 1);
        assert!((t.link_bandwidth(0) - 0.25e9).abs() < 1.0);
        // base list untouched; routes untouched
        assert!((t.links()[0].bandwidth - 1e9).abs() < 1.0);
        assert_eq!(route_matrix(&t), before);
        // repair
        assert_eq!(t.set_link_scale(0, 4, 1.0), 1);
        assert!((t.link_bandwidth(0) - 1e9).abs() < 1.0);
        // unknown links match nothing
        assert_eq!(t.set_link_scale(2, 3, 0.5), 0, "no direct 2->3 link");
    }

    #[test]
    fn remove_restore_roundtrips_routes_byte_identically() {
        // Property over every built-in shape: degrade + partition + full
        // restore reproduces the original route matrix exactly, and
        // recomputation is deterministic (same mutation -> same routes).
        let shapes: Vec<Topology> = vec![
            Topology::fully_connected(4, 1e9, 100),
            Topology::ring(5, 1e9, 100),
            Topology::switched(4, 1e9, 100),
            Topology::hierarchical(2, 2, 100e9, 100, 10e9, 1000),
        ];
        for original in shapes {
            let pristine = route_matrix(&original);
            let mut a = original.clone();
            let mut b = original.clone();
            for t in [&mut a, &mut b] {
                t.set_link_scale(0, 1, 0.5);
                t.isolate_device(1);
                t.restore_device(1);
                t.remove_link(0, 1);
            }
            // determinism: identical mutations yield identical routes
            assert_eq!(route_matrix(&a), route_matrix(&b), "{}", original.name);
            a.restore_all();
            assert_eq!(
                route_matrix(&a),
                pristine,
                "restore_all must reproduce the original routes for {}",
                original.name
            );
            assert!((a.link_bandwidth(0) - original.link_bandwidth(0)).abs() < 1e-6);
        }
    }

    #[test]
    fn full_partition_yields_unreachable_not_panic() {
        let mut t = Topology::switched(3, 1e9, 100);
        assert!(t.reachable(0, 2));
        let cut = t.isolate_device(0);
        assert_eq!(cut, 2, "0<->switch both directions");
        // Unreachable pairs report empty routes and reachable() = false —
        // no panics anywhere.
        assert!(!t.reachable(0, 2));
        assert!(!t.reachable(2, 0));
        assert!(t.route(0, 2).is_empty());
        assert!(t.route(2, 0).is_empty());
        assert!(t.reachable(0, 0), "self is always reachable");
        assert!(t.reachable(1, 2), "unrelated pairs keep their routes");
        assert!(!t.is_connected());
        // heal
        assert_eq!(t.restore_device(0), 2);
        assert!(t.reachable(0, 2) && t.is_connected());
    }
}
