//! Network modeling: topologies, link contention, and collective cost
//! models (all-reduce for TP, all-to-all for expert parallelism, p2p for
//! P/D KV-cache transfer).
//!
//! Links are half-duplex pipes with bandwidth and base latency; transfers
//! serialize on a link according to its outstanding-bytes queue, giving the
//! congestion behaviour §II-C calls out for MoE all-to-all. Collectives are
//! priced with standard ring/pairwise cost models on top of the link fabric.

pub mod topology;

pub use topology::{LinkId, Topology};

use crate::sim::Nanos;

/// Sentinel completion time for transfers across a partitioned fabric:
/// "never". Callers should check [`Fabric::reachable`] before committing a
/// transfer; the sentinel guarantees an unreachable pair is never silently
/// priced as free.
pub const UNREACHABLE: Nanos = Nanos::MAX;

/// A device-to-device fabric for one instance (TP/EP group) or the
/// cross-instance interconnect (P/D transfers, router-to-instance).
#[derive(Debug, Clone)]
pub struct Fabric {
    topo: Topology,
    /// Per-link time at which the link becomes free (serialization queue).
    link_free_at: Vec<Nanos>,
    /// Total bytes moved (for reports).
    pub bytes_moved: u64,
}

impl Fabric {
    pub fn new(topo: Topology) -> Self {
        let n = topo.num_links();
        Fabric {
            topo,
            link_free_at: vec![0; n],
            bytes_moved: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Whether `src` can currently reach `dst` (partitions respected).
    pub fn reachable(&self, src: usize, dst: usize) -> bool {
        self.topo.reachable(src, dst)
    }

    /// Scale the effective bandwidth of every link touching `dev`
    /// (chaos: fabric degradation). Absolute, not compounding.
    pub fn degrade_device(&mut self, dev: usize, scale: f64) -> usize {
        self.topo.scale_device(dev, scale)
    }

    /// Remove every link touching `dev` (chaos: partition). Routes are
    /// recomputed deterministically.
    pub fn isolate_device(&mut self, dev: usize) -> usize {
        self.topo.isolate_device(dev)
    }

    /// Re-add previously removed links touching `dev`.
    pub fn restore_device(&mut self, dev: usize) -> usize {
        self.topo.restore_device(dev)
    }

    /// Clear all degradation and partitions; routes return to pristine.
    /// Link serialization queues are history, not health — they persist.
    pub fn restore_all(&mut self) {
        self.topo.restore_all();
    }

    /// Serialization-aware point-to-point transfer: returns completion time
    /// for `bytes` sent from `src` to `dst` starting at `now`. The transfer
    /// occupies every link on the route back-to-back (store-and-forward at
    /// message granularity — adequate at the 10s-of-MB KV-transfer scale).
    /// Returns [`UNREACHABLE`] (and moves nothing) if the pair is
    /// partitioned.
    pub fn transfer(&mut self, src: usize, dst: usize, bytes: u64, now: Nanos) -> Nanos {
        if src == dst || bytes == 0 {
            return now;
        }
        let route = self.topo.route(src, dst);
        if route.is_empty() {
            return UNREACHABLE;
        }
        let mut t = now;
        for link in route {
            let bw = self.topo.link_bandwidth(link);
            let lat = self.topo.links()[link].latency;
            let start = t.max(self.link_free_at[link]);
            let ser = (bytes as f64 / bw * 1e9).round() as Nanos;
            let done = start + lat + ser;
            self.link_free_at[link] = done;
            t = done;
        }
        self.bytes_moved += bytes;
        t
    }

    /// Non-mutating estimate of a p2p transfer (no queue update). Returns
    /// [`UNREACHABLE`] if the pair is partitioned.
    pub fn estimate(&self, src: usize, dst: usize, bytes: u64) -> Nanos {
        if src == dst || bytes == 0 {
            return 0;
        }
        let route = self.topo.route(src, dst);
        if route.is_empty() {
            return UNREACHABLE;
        }
        route
            .iter()
            .map(|&link| {
                let bw = self.topo.link_bandwidth(link);
                self.topo.links()[link].latency
                    + (bytes as f64 / bw * 1e9).round() as Nanos
            })
            .sum()
    }

    /// Ring all-reduce over the instance's `n` devices for `bytes` per
    /// device: `2*(n-1)/n * bytes` crosses the slowest link in each of
    /// `2*(n-1)` steps.
    pub fn all_reduce(&mut self, n: usize, bytes: u64, now: Nanos) -> Nanos {
        if n <= 1 || bytes == 0 {
            return now;
        }
        let chunk = bytes / n as u64;
        let steps = 2 * (n - 1) as u64;
        let Some((bw, lat)) = self.bottleneck() else {
            return UNREACHABLE;
        };
        let per_step = lat + (chunk as f64 / bw * 1e9).round() as Nanos;
        self.bytes_moved += chunk * steps;
        now + per_step * steps
    }

    /// All-gather over `n` devices (`(n-1)` steps of `bytes/n`).
    pub fn all_gather(&mut self, n: usize, bytes: u64, now: Nanos) -> Nanos {
        if n <= 1 || bytes == 0 {
            return now;
        }
        let chunk = bytes / n as u64;
        let steps = (n - 1) as u64;
        let Some((bw, lat)) = self.bottleneck() else {
            return UNREACHABLE;
        };
        let per_step = lat + (chunk as f64 / bw * 1e9).round() as Nanos;
        self.bytes_moved += chunk * steps;
        now + per_step * steps
    }

    /// Pairwise all-to-all over `n` devices where each device exchanges
    /// `bytes_per_pair` with every other device (the MoE token-dispatch
    /// pattern between attention and expert layers). Skew multiplies the
    /// heaviest pair's traffic: `skew = max_pair / mean_pair`, capturing
    /// gate-imbalance congestion.
    pub fn all_to_all(
        &mut self,
        n: usize,
        bytes_per_pair: u64,
        skew: f64,
        now: Nanos,
    ) -> Nanos {
        if n <= 1 || bytes_per_pair == 0 {
            return now;
        }
        let Some((bw, lat)) = self.bottleneck() else {
            return UNREACHABLE;
        };
        let steps = (n - 1) as u64;
        // Each step, the bottleneck device moves the heaviest pair's bytes.
        let heavy = (bytes_per_pair as f64 * skew.max(1.0)).round() as u64;
        let per_step = lat + (heavy as f64 / bw * 1e9).round() as Nanos;
        self.bytes_moved += bytes_per_pair * steps * n as u64;
        now + per_step * steps
    }

    /// (effective bandwidth, latency) of the slowest live link in the
    /// fabric; `None` when every link is removed (fully partitioned).
    fn bottleneck(&self) -> Option<(f64, Nanos)> {
        let mut found = false;
        let mut bw = f64::INFINITY;
        let mut lat = 0;
        for (id, l) in self.topo.links().iter().enumerate() {
            if self.topo.link_removed(id) {
                continue;
            }
            found = true;
            bw = bw.min(self.topo.link_bandwidth(id));
            lat = lat.max(l.latency);
        }
        found.then_some((bw, lat))
    }
}

#[cfg(test)]
mod tests {
    use super::topology::Topology;
    use super::*;

    fn fc4() -> Fabric {
        // 4 devices, fully connected, 100 GB/s, 1 µs links
        Fabric::new(Topology::fully_connected(4, 100e9, 1_000))
    }

    #[test]
    fn p2p_cost_includes_latency_and_serialization() {
        let mut f = fc4();
        // 100 MB over 100 GB/s = 1 ms + 1 µs latency
        let done = f.transfer(0, 1, 100_000_000, 0);
        assert_eq!(done, 1_000 + 1_000_000);
        assert_eq!(f.bytes_moved, 100_000_000);
    }

    #[test]
    fn contention_serializes_same_link() {
        let mut f = fc4();
        let a = f.transfer(0, 1, 100_000_000, 0);
        let b = f.transfer(0, 1, 100_000_000, 0); // same link, queued behind a
        assert!(b >= a + 1_000_000, "b={b} a={a}");
        // different link unaffected
        let c = f.transfer(2, 3, 100_000_000, 0);
        assert_eq!(c, a);
    }

    #[test]
    fn zero_and_self_transfers_free() {
        let mut f = fc4();
        assert_eq!(f.transfer(0, 0, 1 << 20, 42), 42);
        assert_eq!(f.transfer(0, 1, 0, 42), 42);
    }

    #[test]
    fn ring_allreduce_scales_with_bytes() {
        let mut f = fc4();
        let t1 = f.all_reduce(4, 1 << 20, 0);
        let mut f2 = fc4();
        let t2 = f2.all_reduce(4, 1 << 24, 0);
        assert!(t2 > t1);
        // single device: free
        let mut f3 = fc4();
        assert_eq!(f3.all_reduce(1, 1 << 20, 7), 7);
    }

    #[test]
    fn all_to_all_skew_penalty() {
        let mut f1 = fc4();
        let balanced = f1.all_to_all(4, 1 << 20, 1.0, 0);
        let mut f2 = fc4();
        let skewed = f2.all_to_all(4, 1 << 20, 3.0, 0);
        assert!(
            skewed > balanced * 2,
            "skewed={skewed} balanced={balanced}"
        );
    }

    #[test]
    fn ring_topology_routes_multi_hop() {
        let mut f = Fabric::new(Topology::ring(4, 100e9, 1_000));
        // 0 -> 2 is two hops on a ring
        let direct = f.estimate(0, 1, 1 << 20);
        let two_hop = f.estimate(0, 2, 1 << 20);
        assert!(two_hop > direct);
    }

    #[test]
    fn estimate_matches_uncontended_transfer() {
        let mut f = fc4();
        let est = f.estimate(0, 3, 5_000_000);
        let act = f.transfer(0, 3, 5_000_000, 0);
        assert_eq!(est, act);
    }

    #[test]
    fn degraded_link_slows_transfers_and_restore_heals() {
        let mut f = fc4();
        let healthy = f.estimate(0, 1, 100_000_000);
        f.degrade_device(0, 0.5);
        let degraded = f.estimate(0, 1, 100_000_000);
        assert!(
            degraded > healthy,
            "degraded={degraded} healthy={healthy}"
        );
        f.restore_all();
        assert_eq!(f.estimate(0, 1, 100_000_000), healthy);
    }

    #[test]
    fn partition_makes_transfers_unreachable_not_free() {
        let mut f = fc4();
        f.isolate_device(2);
        assert!(!f.reachable(0, 2));
        assert_eq!(f.estimate(0, 2, 1 << 20), UNREACHABLE);
        let before = f.bytes_moved;
        assert_eq!(f.transfer(0, 2, 1 << 20, 0), UNREACHABLE);
        assert_eq!(f.bytes_moved, before, "partitioned transfer moved bytes");
        // other pairs unaffected; healing restores service
        assert!(f.reachable(0, 1));
        f.restore_device(2);
        assert!(f.reachable(0, 2));
        assert!(f.transfer(0, 2, 1 << 20, 0) < UNREACHABLE);
    }

    #[test]
    fn fully_partitioned_collectives_return_sentinel() {
        let mut f = Fabric::new(Topology::ring(4, 100e9, 1_000));
        for d in 0..4 {
            f.isolate_device(d);
        }
        assert_eq!(f.all_reduce(4, 1 << 20, 0), UNREACHABLE);
        assert_eq!(f.all_gather(4, 1 << 20, 0), UNREACHABLE);
        assert_eq!(f.all_to_all(4, 1 << 20, 1.0, 0), UNREACHABLE);
    }
}
