//! Global request router (§II-B): sits outside the instances, dispatches
//! every arriving request according to the configured policy, and picks
//! decode targets for P/D KV hand-offs.
//!
//! Policies see a compact [`InstanceView`] snapshot (load, KV pressure,
//! prefix-cache match, role) — the same signals the paper lists: "load
//! balancing, workload characteristics, and the state of prefix caches".
//! New policies implement [`RoutePolicy`]; the built-ins cover the enum in
//! `config::RouterPolicy`.

use std::collections::HashMap;

use crate::config::{Role, RouterPolicy};
use crate::workload::Request;

/// Router-visible snapshot of one instance.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: usize,
    pub role: Role,
    /// Waiting + running requests.
    pub outstanding: usize,
    /// KV pool utilization in [0, 1].
    pub kv_utilization: f64,
    /// Longest prefix-cache match for the request being routed (tokens).
    pub prefix_match: u64,
    /// Whether the instance serves this request's model.
    pub compatible: bool,
}

/// A routing decision strategy. Implement this to plug in custom policies.
pub trait RoutePolicy: Send {
    /// Choose among `candidates` (non-empty, already filtered to
    /// prefill-capable + model-compatible instances).
    fn choose(&mut self, req: &Request, candidates: &[InstanceView]) -> usize;

    fn name(&self) -> &str;
}

/// The global router: policy + session-affinity memory + RR cursor.
pub struct GlobalRouter {
    policy: Box<dyn RoutePolicy>,
    affinity: HashMap<u64, usize>,
    pub dispatched: u64,
}

impl GlobalRouter {
    pub fn new(policy: RouterPolicy) -> Self {
        let policy: Box<dyn RoutePolicy> = match policy {
            RouterPolicy::RoundRobin => Box::new(RoundRobin { cursor: 0 }),
            RouterPolicy::LeastOutstanding => Box::new(LeastOutstanding),
            RouterPolicy::LeastKvLoad => Box::new(LeastKvLoad),
            RouterPolicy::PrefixAware => Box::new(PrefixAware),
            RouterPolicy::SessionAffinity => Box::new(LeastOutstanding),
        };
        GlobalRouter {
            policy,
            affinity: HashMap::new(),
            dispatched: 0,
        }
    }

    pub fn custom(policy: Box<dyn RoutePolicy>) -> Self {
        GlobalRouter {
            policy,
            affinity: HashMap::new(),
            dispatched: 0,
        }
    }

    /// Route an arriving request to a prefill-capable instance.
    /// `session_affinity` enables sticky sessions on top of any policy.
    pub fn dispatch(
        &mut self,
        req: &Request,
        views: &[InstanceView],
        session_affinity: bool,
    ) -> Option<usize> {
        let candidates: Vec<InstanceView> = views
            .iter()
            .filter(|v| v.compatible && matches!(v.role, Role::Unified | Role::Prefill))
            .cloned()
            .collect();
        if candidates.is_empty() {
            return None;
        }
        if session_affinity {
            if let Some(&inst) = self.affinity.get(&req.session) {
                if candidates.iter().any(|v| v.id == inst) {
                    self.dispatched += 1;
                    return Some(inst);
                }
            }
        }
        let chosen = self.policy.choose(req, &candidates);
        debug_assert!(candidates.iter().any(|v| v.id == chosen));
        if session_affinity {
            self.affinity.insert(req.session, chosen);
        }
        self.dispatched += 1;
        Some(chosen)
    }

    /// Pick a decode instance for a P/D KV hand-off (least outstanding
    /// among decode-role instances).
    pub fn pick_decode(&mut self, views: &[InstanceView]) -> Option<usize> {
        views
            .iter()
            .filter(|v| v.compatible && v.role == Role::Decode)
            .min_by(|a, b| {
                (a.outstanding, a.id).cmp(&(b.outstanding, b.id))
            })
            .map(|v| v.id)
    }

    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }
}

// ---------------------------------------------------------------------------
// Built-in policies
// ---------------------------------------------------------------------------

struct RoundRobin {
    cursor: usize,
}

impl RoutePolicy for RoundRobin {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        let v = &candidates[self.cursor % candidates.len()];
        self.cursor = self.cursor.wrapping_add(1);
        v.id
    }
    fn name(&self) -> &str {
        "round-robin"
    }
}

struct LeastOutstanding;

impl RoutePolicy for LeastOutstanding {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| (a.outstanding, a.id).cmp(&(b.outstanding, b.id)))
            .unwrap()
            .id
    }
    fn name(&self) -> &str {
        "least-outstanding"
    }
}

struct LeastKvLoad;

impl RoutePolicy for LeastKvLoad {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| {
                a.kv_utilization
                    .partial_cmp(&b.kv_utilization)
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            })
            .unwrap()
            .id
    }
    fn name(&self) -> &str {
        "least-kv"
    }
}

/// Prefer the longest prefix-cache match; break ties by load. A match is
/// only honored when it saves meaningful work (>= 16 tokens), otherwise
/// falls back to load balancing.
struct PrefixAware;

impl RoutePolicy for PrefixAware {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        let best = candidates.iter().map(|v| v.prefix_match).max().unwrap_or(0);
        if best >= 16 {
            candidates
                .iter()
                .filter(|v| v.prefix_match == best)
                .min_by(|a, b| (a.outstanding, a.id).cmp(&(b.outstanding, b.id)))
                .unwrap()
                .id
        } else {
            LeastOutstanding.choose(_req, candidates)
        }
    }
    fn name(&self) -> &str {
        "prefix-aware"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, role: Role, outstanding: usize) -> InstanceView {
        InstanceView {
            id,
            role,
            outstanding,
            kv_utilization: 0.0,
            prefix_match: 0,
            compatible: true,
        }
    }

    fn req(id: u64, session: u64) -> Request {
        Request {
            id,
            arrival: 0,
            prompt_tokens: 64,
            output_tokens: 8,
            session,
            shared_prefix: 0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = GlobalRouter::new(RouterPolicy::RoundRobin);
        let views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 0)];
        let picks: Vec<usize> = (0..4)
            .map(|i| r.dispatch(&req(i, i), &views, false).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn least_outstanding_balances() {
        let mut r = GlobalRouter::new(RouterPolicy::LeastOutstanding);
        let views = vec![view(0, Role::Unified, 5), view(1, Role::Unified, 2)];
        assert_eq!(r.dispatch(&req(0, 0), &views, false), Some(1));
    }

    #[test]
    fn least_kv_prefers_free_memory() {
        let mut r = GlobalRouter::new(RouterPolicy::LeastKvLoad);
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 9)];
        views[0].kv_utilization = 0.9;
        views[1].kv_utilization = 0.1;
        assert_eq!(r.dispatch(&req(0, 0), &views, false), Some(1));
    }

    #[test]
    fn prefix_aware_follows_cache() {
        let mut r = GlobalRouter::new(RouterPolicy::PrefixAware);
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 9)];
        views[1].prefix_match = 128;
        assert_eq!(r.dispatch(&req(0, 0), &views, false), Some(1));
        // tiny match falls back to load
        views[1].prefix_match = 4;
        assert_eq!(r.dispatch(&req(1, 1), &views, false), Some(0));
    }

    #[test]
    fn session_affinity_sticks() {
        let mut r = GlobalRouter::new(RouterPolicy::SessionAffinity);
        let views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 0)];
        let first = r.dispatch(&req(0, 42), &views, true).unwrap();
        // same session, now-busier instance: still sticks
        let mut views2 = views.clone();
        views2[first].outstanding = 100;
        assert_eq!(r.dispatch(&req(1, 42), &views2, true), Some(first));
        // different session balances away
        assert_ne!(r.dispatch(&req(2, 43), &views2, true), Some(first));
    }

    #[test]
    fn decode_instances_not_dispatch_targets() {
        let mut r = GlobalRouter::new(RouterPolicy::RoundRobin);
        let views = vec![view(0, Role::Decode, 0), view(1, Role::Prefill, 0)];
        assert_eq!(r.dispatch(&req(0, 0), &views, false), Some(1));
    }

    #[test]
    fn pick_decode_least_loaded() {
        let mut r = GlobalRouter::new(RouterPolicy::RoundRobin);
        let views = vec![
            view(0, Role::Prefill, 0),
            view(1, Role::Decode, 3),
            view(2, Role::Decode, 1),
        ];
        assert_eq!(r.pick_decode(&views), Some(2));
    }

    #[test]
    fn no_candidates_none() {
        let mut r = GlobalRouter::new(RouterPolicy::RoundRobin);
        assert_eq!(r.dispatch(&req(0, 0), &[], false), None);
        let views = vec![view(0, Role::Decode, 0)];
        assert_eq!(r.dispatch(&req(0, 0), &views, false), None);
    }

    #[test]
    fn incompatible_filtered() {
        let mut r = GlobalRouter::new(RouterPolicy::LeastOutstanding);
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 5)];
        views[0].compatible = false;
        assert_eq!(r.dispatch(&req(0, 0), &views, false), Some(1));
    }
}
