//! Global request router (§II-B): sits outside the instances, dispatches
//! every arriving request according to the configured policy, and picks
//! decode targets for P/D KV hand-offs.
//!
//! Policies see a compact [`InstanceView`] snapshot (load, KV pressure,
//! prefix-cache match, role) — the same signals the paper lists: "load
//! balancing, workload characteristics, and the state of prefix caches".
//! New policies implement [`RoutePolicy`] and register in the
//! [`policy registry`](crate::policy); the built-ins below back the
//! registry's `round-robin`, `least-outstanding`, `least-kv`,
//! `prefix-aware`, and `session-affinity` entries.

use crate::util::fxhash::FxHashMap;

use crate::config::Role;
use crate::workload::Request;

/// Router-visible snapshot of one instance.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: usize,
    pub role: Role,
    /// Waiting + running requests.
    pub outstanding: usize,
    /// KV pool utilization in [0, 1].
    pub kv_utilization: f64,
    /// Longest prefix-cache match for the request being routed (tokens).
    pub prefix_match: u64,
    /// Whether the instance serves this request's model.
    pub compatible: bool,
}

/// A routing decision strategy. Implement this to plug in custom policies.
pub trait RoutePolicy: Send {
    /// Choose among `candidates` (non-empty, already filtered to
    /// prefill-capable + model-compatible instances).
    fn choose(&mut self, req: &Request, candidates: &[InstanceView]) -> usize;

    fn name(&self) -> &str;
}

/// The global router: a resolved [`RoutePolicy`] plus dispatch accounting.
///
/// Session stickiness is no longer a router-level flag: it lives in the
/// [`SessionAffinity`] wrapper policy, so any policy can be made sticky and
/// reports attribute decisions to the policy that actually made them.
pub struct GlobalRouter {
    policy: Box<dyn RoutePolicy>,
    pub dispatched: u64,
    /// Reused candidate buffer — dispatch runs once per arrival, so the
    /// filtered snapshot is rebuilt in place instead of allocated.
    candidates: Vec<InstanceView>,
}

impl GlobalRouter {
    /// Wrap an already-resolved policy (see
    /// [`PolicyRegistry::make_route`](crate::policy::PolicyRegistry::make_route)).
    pub fn new(policy: Box<dyn RoutePolicy>) -> Self {
        GlobalRouter {
            policy,
            dispatched: 0,
            candidates: vec![],
        }
    }

    /// Route an arriving request to a prefill-capable instance.
    pub fn dispatch(&mut self, req: &Request, views: &[InstanceView]) -> Option<usize> {
        self.candidates.clear();
        self.candidates.extend(
            views
                .iter()
                .filter(|v| {
                    v.compatible && matches!(v.role, Role::Unified | Role::Prefill)
                })
                .cloned(),
        );
        let candidates = &self.candidates;
        if candidates.is_empty() {
            return None;
        }
        let chosen = self.policy.choose(req, candidates);
        // Hard check even in release: custom policies are the headline API,
        // and the natural bug — returning a slice *index* instead of a
        // candidate *id* — would otherwise silently misroute to a filtered
        // -out (wrong-role or incompatible) instance.
        assert!(
            candidates.iter().any(|v| v.id == chosen),
            "route policy '{}' chose instance {}, which is not a candidate \
             (candidate ids: {:?}); RoutePolicy::choose must return the `id` \
             field of one of the views it was given",
            self.policy.name(),
            chosen,
            // simlint: allow(H01) — assert message: built only when the
            // route-policy contract is already violated
            candidates.iter().map(|v| v.id).collect::<Vec<_>>()
        );
        self.dispatched += 1;
        Some(chosen)
    }

    /// Pick a decode instance for a P/D KV hand-off (least outstanding
    /// among decode-role instances).
    pub fn pick_decode(&mut self, views: &[InstanceView]) -> Option<usize> {
        views
            .iter()
            .filter(|v| v.compatible && v.role == Role::Decode)
            .min_by(|a, b| {
                (a.outstanding, a.id).cmp(&(b.outstanding, b.id))
            })
            .map(|v| v.id)
    }

    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }
}

// ---------------------------------------------------------------------------
// Built-in policies
// ---------------------------------------------------------------------------

/// Cycle through candidates in arrival order.
#[derive(Debug, Default)]
pub struct RoundRobin {
    cursor: usize,
}

impl RoutePolicy for RoundRobin {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        let v = &candidates[self.cursor % candidates.len()];
        self.cursor = self.cursor.wrapping_add(1);
        v.id
    }
    fn name(&self) -> &str {
        "round-robin"
    }
}

/// Fewest outstanding (waiting + running) requests.
#[derive(Debug, Default)]
pub struct LeastOutstanding;

impl RoutePolicy for LeastOutstanding {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| (a.outstanding, a.id).cmp(&(b.outstanding, b.id)))
            // simlint: allow(S01) — trait contract: candidates is non-empty
            .unwrap()
            .id
    }
    fn name(&self) -> &str {
        "least-outstanding"
    }
}

/// Lowest KV-block utilization.
#[derive(Debug, Default)]
pub struct LeastKvLoad;

impl RoutePolicy for LeastKvLoad {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        candidates
            .iter()
            .min_by(|a, b| {
                a.kv_utilization
                    .partial_cmp(&b.kv_utilization)
                    // simlint: allow(S01) — kv_utilization is a finite ratio in [0, 1], never NaN
                    .unwrap()
                    .then(a.id.cmp(&b.id))
            })
            // simlint: allow(S01) — trait contract: candidates is non-empty
            .unwrap()
            .id
    }
    fn name(&self) -> &str {
        "least-kv"
    }
}

/// Prefer the longest prefix-cache match; break ties by load. A match is
/// only honored when it saves meaningful work (>= 16 tokens), otherwise
/// falls back to load balancing.
#[derive(Debug, Default)]
pub struct PrefixAware;

impl RoutePolicy for PrefixAware {
    fn choose(&mut self, _req: &Request, candidates: &[InstanceView]) -> usize {
        let best = candidates.iter().map(|v| v.prefix_match).max().unwrap_or(0);
        if best >= 16 {
            candidates
                .iter()
                .filter(|v| v.prefix_match == best)
                .min_by(|a, b| (a.outstanding, a.id).cmp(&(b.outstanding, b.id)))
                // simlint: allow(S01) — filter keeps the argmax element, so the set is non-empty
                .unwrap()
                .id
        } else {
            LeastOutstanding.choose(_req, candidates)
        }
    }
    fn name(&self) -> &str {
        "prefix-aware"
    }
}

/// Stick every session to the instance that served its first request; the
/// wrapped fallback policy places that first request (and any request whose
/// pinned instance is no longer a candidate).
///
/// This is a *wrapper*, not a standalone policy: the registry's
/// `session-affinity` entry wraps [`LeastOutstanding`], and the reported
/// name spells out the fallback (`session-affinity(least-outstanding)`) so
/// reports never silently attribute placement to the wrong policy.
pub struct SessionAffinity {
    inner: Box<dyn RoutePolicy>,
    affinity: FxHashMap<u64, usize>,
    name: String,
}

impl SessionAffinity {
    /// Make `inner` session-sticky.
    pub fn wrapping(inner: Box<dyn RoutePolicy>) -> Self {
        let name = format!("session-affinity({})", inner.name());
        SessionAffinity {
            inner,
            affinity: FxHashMap::default(),
            name,
        }
    }
}

impl RoutePolicy for SessionAffinity {
    fn choose(&mut self, req: &Request, candidates: &[InstanceView]) -> usize {
        if let Some(&pinned) = self.affinity.get(&req.session) {
            if candidates.iter().any(|v| v.id == pinned) {
                return pinned;
            }
        }
        let chosen = self.inner.choose(req, candidates);
        self.affinity.insert(req.session, chosen);
        chosen
    }
    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, role: Role, outstanding: usize) -> InstanceView {
        InstanceView {
            id,
            role,
            outstanding,
            kv_utilization: 0.0,
            prefix_match: 0,
            compatible: true,
        }
    }

    fn req(id: u64, session: u64) -> Request {
        Request {
            id,
            prompt_tokens: 64,
            output_tokens: 8,
            session,
            ..Request::default()
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = GlobalRouter::new(Box::new(RoundRobin::default()));
        let views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 0)];
        let picks: Vec<usize> = (0..4)
            .map(|i| r.dispatch(&req(i, i), &views).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn least_outstanding_balances() {
        let mut r = GlobalRouter::new(Box::new(LeastOutstanding));
        let views = vec![view(0, Role::Unified, 5), view(1, Role::Unified, 2)];
        assert_eq!(r.dispatch(&req(0, 0), &views), Some(1));
    }

    #[test]
    fn least_kv_prefers_free_memory() {
        let mut r = GlobalRouter::new(Box::new(LeastKvLoad));
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 9)];
        views[0].kv_utilization = 0.9;
        views[1].kv_utilization = 0.1;
        assert_eq!(r.dispatch(&req(0, 0), &views), Some(1));
    }

    #[test]
    fn prefix_aware_follows_cache() {
        let mut r = GlobalRouter::new(Box::new(PrefixAware));
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 9)];
        views[1].prefix_match = 128;
        assert_eq!(r.dispatch(&req(0, 0), &views), Some(1));
        // tiny match falls back to load
        views[1].prefix_match = 4;
        assert_eq!(r.dispatch(&req(1, 1), &views), Some(0));
    }

    #[test]
    fn session_affinity_sticks() {
        let mut r = GlobalRouter::new(Box::new(SessionAffinity::wrapping(
            Box::new(LeastOutstanding),
        )));
        let views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 0)];
        let first = r.dispatch(&req(0, 42), &views).unwrap();
        // same session, now-busier instance: still sticks
        let mut views2 = views.clone();
        views2[first].outstanding = 100;
        assert_eq!(r.dispatch(&req(1, 42), &views2), Some(first));
        // different session balances away
        assert_ne!(r.dispatch(&req(2, 43), &views2), Some(first));
    }

    #[test]
    fn session_affinity_name_reports_fallback() {
        let p = SessionAffinity::wrapping(Box::new(LeastOutstanding));
        assert_eq!(p.name(), "session-affinity(least-outstanding)");
        let r = GlobalRouter::new(Box::new(p));
        assert_eq!(r.policy_name(), "session-affinity(least-outstanding)");
    }

    #[test]
    fn session_affinity_repins_when_pin_invalid() {
        let mut p = SessionAffinity::wrapping(Box::new(LeastOutstanding));
        let views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 5)];
        assert_eq!(p.choose(&req(0, 7), &views), 0);
        // pinned instance no longer a candidate -> falls back + repins
        let only1 = vec![view(1, Role::Unified, 5)];
        assert_eq!(p.choose(&req(1, 7), &only1), 1);
        assert_eq!(p.choose(&req(2, 7), &views), 1, "repinned to instance 1");
    }

    #[test]
    fn decode_instances_not_dispatch_targets() {
        let mut r = GlobalRouter::new(Box::new(RoundRobin::default()));
        let views = vec![view(0, Role::Decode, 0), view(1, Role::Prefill, 0)];
        assert_eq!(r.dispatch(&req(0, 0), &views), Some(1));
    }

    #[test]
    fn pick_decode_least_loaded() {
        let mut r = GlobalRouter::new(Box::new(RoundRobin::default()));
        let views = vec![
            view(0, Role::Prefill, 0),
            view(1, Role::Decode, 3),
            view(2, Role::Decode, 1),
        ];
        assert_eq!(r.pick_decode(&views), Some(2));
    }

    #[test]
    fn no_candidates_none() {
        let mut r = GlobalRouter::new(Box::new(RoundRobin::default()));
        assert_eq!(r.dispatch(&req(0, 0), &[]), None);
        let views = vec![view(0, Role::Decode, 0)];
        assert_eq!(r.dispatch(&req(0, 0), &views), None);
    }

    #[test]
    fn incompatible_filtered() {
        let mut r = GlobalRouter::new(Box::new(LeastOutstanding));
        let mut views = vec![view(0, Role::Unified, 0), view(1, Role::Unified, 5)];
        views[0].compatible = false;
        assert_eq!(r.dispatch(&req(0, 0), &views), Some(1));
    }

    #[test]
    #[should_panic(expected = "not a candidate")]
    fn policy_returning_non_candidate_id_is_caught() {
        // The natural custom-policy bug: returning a slice index instead of
        // a candidate id. Views 5 and 7 make every index a non-id.
        struct IndexNotId;
        impl RoutePolicy for IndexNotId {
            fn choose(&mut self, _req: &Request, _c: &[InstanceView]) -> usize {
                0 // "first candidate" — but as an index, not an id
            }
            fn name(&self) -> &str {
                "index-not-id"
            }
        }
        let mut r = GlobalRouter::new(Box::new(IndexNotId));
        let views = vec![view(5, Role::Unified, 0), view(7, Role::Unified, 0)];
        let _ = r.dispatch(&req(0, 0), &views);
    }
}
