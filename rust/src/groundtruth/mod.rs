//! Ground-truth execution engine: the stand-in for the paper's real
//! vLLM-on-GPU baseline in Fig. 2 (DESIGN.md §1 substitution table).
//!
//! [`ExecPerfModel`] implements [`PerfModel`] by **actually executing** the
//! compiled HLO operator on the CPU PJRT client and returning measured
//! wall-clock time. Running the regular [`crate::coordinator::Simulation`]
//! with this model is a *real execution* of the serving system: every
//! engine iteration's cost is the genuine runtime of its operators on this
//! machine, including allocator jitter, cache effects, and batch-shape
//! dependence. The trace-driven simulator must then reproduce this system's
//! TPOT/ITL/throughput from profiled traces alone — exactly the paper's
//! validation setup, with CPU-PJRT standing in for the 4x RTX 3090 testbed.
//!
//! Invocation shapes are quantized to the nearest artifact grid point (the
//! grid is the set of shapes that exist as compiled executables). The same
//! quantization is NOT applied to the trace side — the simulator
//! interpolates — so grid mismatch is a genuine source of validation error,
//! as in the paper.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::{OpInvocation, OpKind};
use crate::perf::PerfModel;
use crate::runtime::{Manifest, OpArtifact, Runtime};
use crate::sim::Nanos;

/// Thread-safe monotonically-updated diagnostic counter. Keeps the old
/// `Cell`-era `get`/`set` call surface while making [`ExecPerfModel`]
/// `Sync`, as the `PerfModel` contract now requires.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new(v: u64) -> Counter {
        Counter(AtomicU64::new(v))
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
}

/// Executes operators for real to price them.
pub struct ExecPerfModel {
    /// The PJRT runtime, serialized behind a mutex: PJRT execution is
    /// inherently sequential on the CPU client, and the lock makes the
    /// model `Sync` so ground-truth simulations can cross threads.
    inner: Mutex<Runtime>,
    ops: Vec<OpArtifact>,
    name: String,
    /// Per-op-kind dispatch-overhead floor (ns), estimated during warm-up
    /// as the smallest-shape artifact's latency. Off-grid scaling applies
    /// only to the work above this floor — fixed dispatch cost does not
    /// grow with shape.
    overhead: Vec<u64>,
    /// Total real execution time spent (diagnostics).
    pub exec_ns: Counter,
    pub executions: Counter,
}

impl ExecPerfModel {
    /// Build for one model from the artifacts directory.
    ///
    /// All artifacts are compiled and executed once up front ("engine
    /// warm-up", as a real serving stack does before accepting traffic) so
    /// that measured op latencies never include JIT compilation.
    pub fn new(artifacts_root: &Path, model: &str) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_root)?;
        let mm = manifest
            .model(model)
            .ok_or_else(|| anyhow::anyhow!("model '{model}' not in manifest"))?;
        let mut runtime = Runtime::cpu(artifacts_root)?;
        // simlint: allow(D02) — wall-clock timing of the real PJRT execution being
        // profiled; never feeds simulated time
        let t0 = std::time::Instant::now();
        let mut overhead = vec![u64::MAX; OpKind::all().len()];
        for art in &mm.ops {
            let loaded = runtime.load(art)?;
            loaded.execute_timed()?;
            let warm = loaded.execute_timed()?;
            let idx = OpKind::all().iter().position(|&k| k == art.kind).unwrap();
            overhead[idx] = overhead[idx].min(warm);
        }
        for o in &mut overhead {
            if *o == u64::MAX {
                *o = 0;
            }
        }
        log::info!(
            "ground-truth engine warm-up: {} ops in {:.1} s",
            mm.ops.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(ExecPerfModel {
            inner: Mutex::new(runtime),
            ops: mm.ops.clone(),
            name: format!("exec[{model}]"),
            overhead,
            exec_ns: Counter::new(0),
            executions: Counter::new(0),
        })
    }

    /// Nearest artifact for an invocation (log-space nearest on each axis).
    fn nearest(&self, inv: OpInvocation) -> Option<&OpArtifact> {
        let dist = |a: u64, b: u64| -> f64 {
            let (a, b) = (a.max(1) as f64, b.max(1) as f64);
            (a.ln() - b.ln()).abs()
        };
        self.ops
            .iter()
            .filter(|o| o.kind == inv.kind)
            .min_by(|x, y| {
                let dx = if inv.kind.is_decode_grid() {
                    dist(x.batch, inv.tokens) + dist(x.ctx, inv.ctx)
                } else {
                    dist(x.tokens, inv.tokens)
                };
                let dy = if inv.kind.is_decode_grid() {
                    dist(y.batch, inv.tokens) + dist(y.ctx, inv.ctx)
                } else {
                    dist(y.tokens, inv.tokens)
                };
                dx.partial_cmp(&dy).unwrap()
            })
    }
}

impl PerfModel for ExecPerfModel {
    // simlint: cold — ground-truth mode executes real kernels through PJRT
    // (milliseconds per op); allocation on this path is irrelevant next to
    // the execution itself, and the events/sec contract never applies to it.
    fn op_latency(&self, inv: OpInvocation) -> Nanos {
        let art = self
            .nearest(inv)
            .unwrap_or_else(|| panic!("no artifact for op {}", inv.kind))
            .clone();
        let mut rt = self.inner.lock().unwrap();
        let loaded = rt
            .load(&art)
            .unwrap_or_else(|e| panic!("loading {}: {e}", art.name));
        // min-of-2 real executions: same low-noise estimator the profiler
        // uses, so reference and prediction share measurement semantics.
        let m1 = loaded
            .execute_timed()
            .unwrap_or_else(|e| panic!("executing {}: {e}", art.name));
        let m2 = loaded
            .execute_timed()
            .unwrap_or_else(|e| panic!("executing {}: {e}", art.name));
        let measured = m1.min(m2);
        // Scale the measured grid-point latency by the true/artifact work
        // ratio so off-grid shapes aren't systematically mis-priced (the
        // artifact is the nearest executable shape, not the exact one).
        let scale = match inv.kind {
            OpKind::AttnDecode => {
                (inv.tokens.max(1) as f64 / art.batch.max(1) as f64)
                    * (inv.ctx.max(1) as f64 / art.ctx.max(1) as f64)
            }
            OpKind::AttnPrefill => {
                let r = inv.tokens.max(1) as f64 / art.tokens.max(1) as f64;
                r * r // attention is quadratic in sequence length
            }
            _ => inv.tokens.max(1) as f64 / art.tokens.max(1) as f64,
        };
        // Linear work-ratio scaling: the trace side interpolates linearly
        // between grid points, so reference and prediction share the same
        // shape-response model and residual error reflects genuine dynamics.
        let _ = &self.overhead;
        let ns = (measured as f64 * scale).round() as u64;
        self.exec_ns.add(measured);
        self.executions.add(1);
        ns.max(1)
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Artifacts on disk AND a real PJRT backend compiled in (the in-repo
    /// xla stub cannot execute, so these tests must skip with it).
    fn have_artifacts() -> bool {
        artifacts_root().join("manifest.json").exists()
            && crate::runtime::Runtime::backend_available()
    }

    #[test]
    fn prices_by_real_execution() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = ExecPerfModel::new(&artifacts_root(), "tiny-dense").unwrap();
        let l = m.op_latency(OpInvocation::tokens(OpKind::Ffn, 64));
        assert!(l > 0);
        assert_eq!(m.executions.get(), 1);
        assert!(m.exec_ns.get() > 0);
    }

    #[test]
    fn off_grid_shapes_scale() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = ExecPerfModel::new(&artifacts_root(), "tiny-dense").unwrap();
        // warm both (compile noise out)
        m.op_latency(OpInvocation::tokens(OpKind::LmHead, 64));
        let small: Vec<u64> = (0..3)
            .map(|_| m.op_latency(OpInvocation::tokens(OpKind::LmHead, 48)))
            .collect();
        let large: Vec<u64> = (0..3)
            .map(|_| m.op_latency(OpInvocation::tokens(OpKind::LmHead, 480)))
            .collect();
        let s = small.iter().min().unwrap();
        let l = large.iter().min().unwrap();
        assert!(l > s, "large {l} !> small {s}");
    }

    #[test]
    fn end_to_end_groundtruth_simulation() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        use crate::config::presets;
        use crate::coordinator::Simulation;
        use std::sync::Arc;
        let mut cfg = presets::single_dense("tiny-dense", "cpu-pjrt");
        cfg.workload.num_requests = 5;
        cfg.workload.lengths = crate::workload::LengthDist::short();
        let gt = Arc::new(ExecPerfModel::new(&artifacts_root(), "tiny-dense").unwrap());
        let gt2 = gt.clone();
        let mut sim = Simulation::builder(cfg)
            .with_perf_factory(move |_, _, _| {
                Ok(gt2.clone() as Arc<dyn crate::perf::PerfModel>)
            })
            .build()
            .unwrap();
        let report = sim.run();
        assert_eq!(report.num_finished, 5);
        assert!(gt.executions.get() > 0);
    }
}
