//! simlint CLI — gate the tree on the determinism & invariant rules.
//!
//! Usage:
//!
//! ```text
//! simlint --check <path>... [--baseline <file>] [--report <file>] [--format text|json]
//! simlint --check <path>... --update-baseline [--baseline <file>]
//! ```
//!
//! * `--check <path>` — one or more files or directories to scan (`.rs`
//!   files, recursively). CI runs `--check rust/src` from the repo root.
//!   All paths are analyzed as **one** set: the flow-aware rules (H01/H02
//!   call-graph reachability, P01 registry/doc consistency) see every file
//!   together, with README.md/DESIGN.md discovered by walking up from the
//!   first root.
//! * `--baseline <file>` — grandfather file; defaults to `simlint.allow`
//!   next to the first checked root (`rust/simlint.allow` for
//!   `--check rust/src`). A missing baseline is treated as empty.
//! * `--report <file>` — write the full findings report (including
//!   baselined findings, marked as such) to a file for CI artifacts.
//! * `--format text|json` — report format (default `text`). `json` emits a
//!   sorted-key `simlint/v2` document with a stable `id` per finding
//!   (FNV-1a over rule/path/line-text), for machine consumption.
//! * `--update-baseline` — rewrite the baseline from the current findings
//!   and exit 0. The serializer is canonical (sorted, deduplicated), so
//!   running it twice is byte-identical.
//!
//! Exit codes: **0** clean (or baseline updated), **1** unbaselined
//! findings, **2** usage or I/O error.

use llmservingsim::lint::baseline::{format_baseline, Baseline};
use llmservingsim::lint::{analyze_paths, report_json, Finding};
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(PartialEq)]
enum Format {
    Text,
    Json,
}

struct Args {
    roots: Vec<PathBuf>,
    baseline: Option<PathBuf>,
    report: Option<PathBuf>,
    format: Format,
    update_baseline: bool,
}

fn parse_args(argv: &[String]) -> Result<Args, String> {
    let mut args = Args {
        roots: Vec::new(),
        baseline: None,
        report: None,
        format: Format::Text,
        update_baseline: false,
    };
    let mut i = 0usize;
    while i < argv.len() {
        match argv[i].as_str() {
            "--check" => {} // mode marker; the paths that follow are roots
            "--update-baseline" => args.update_baseline = true,
            "--baseline" => {
                i += 1;
                let v = argv.get(i).ok_or("--baseline needs a path")?;
                args.baseline = Some(PathBuf::from(v));
            }
            "--report" => {
                i += 1;
                let v = argv.get(i).ok_or("--report needs a path")?;
                args.report = Some(PathBuf::from(v));
            }
            "--format" => {
                i += 1;
                match argv.get(i).map(String::as_str) {
                    Some("text") => args.format = Format::Text,
                    Some("json") => args.format = Format::Json,
                    _ => return Err("--format needs `text` or `json`".to_string()),
                }
            }
            "--help" | "-h" => return Err("help".to_string()),
            flag if flag.starts_with('-') => {
                return Err(format!("unknown flag `{flag}`"));
            }
            path => args.roots.push(PathBuf::from(path)),
        }
        i += 1;
    }
    if args.roots.is_empty() {
        return Err("no paths given — try `simlint --check rust/src`".to_string());
    }
    Ok(args)
}

fn default_baseline(roots: &[PathBuf]) -> PathBuf {
    roots[0]
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("simlint.allow")
}

fn scan_roots(roots: &[PathBuf]) -> std::io::Result<Vec<Finding>> {
    // One analysis over the union: the flow-aware rules need the cross-file
    // call graph, so roots are not scanned independently.
    analyze_paths(roots)
}

fn render_report(fresh: &[Finding], baselined: &[Finding], files_note: &str) -> String {
    let mut out = String::new();
    out.push_str("simlint findings report\n");
    out.push_str("=======================\n");
    out.push_str(files_note);
    out.push('\n');
    for f in fresh {
        out.push_str(&f.render());
        out.push('\n');
    }
    for f in baselined {
        out.push_str("[baselined] ");
        out.push_str(&f.render());
        out.push('\n');
    }
    out.push_str(&format!(
        "\n{} finding(s), {} baselined, {} gating\n",
        fresh.len() + baselined.len(),
        baselined.len(),
        fresh.len()
    ));
    out
}

fn run(argv: &[String]) -> Result<ExitCode, String> {
    let args = match parse_args(argv) {
        Ok(a) => a,
        Err(e) if e == "help" => {
            println!(
                "simlint --check <path>... [--baseline <file>] [--report <file>] [--format text|json] [--update-baseline]"
            );
            return Ok(ExitCode::SUCCESS);
        }
        Err(e) => return Err(e),
    };

    let findings = scan_roots(&args.roots).map_err(|e| format!("scan failed: {e}"))?;

    let baseline_path = args
        .baseline
        .clone()
        .unwrap_or_else(|| default_baseline(&args.roots));

    if args.update_baseline {
        let text = format_baseline(&findings);
        std::fs::write(&baseline_path, &text)
            .map_err(|e| format!("cannot write {}: {e}", baseline_path.display()))?;
        println!(
            "simlint: wrote {} entr{} to {}",
            findings.len(),
            if findings.len() == 1 { "y" } else { "ies" },
            baseline_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => Baseline::parse(&text),
        Err(_) => Baseline::default(),
    };

    let (baselined, fresh): (Vec<Finding>, Vec<Finding>) =
        findings.into_iter().partition(|f| baseline.contains(f));

    let files_note = format!(
        "roots: {} | baseline: {} ({} entr{})\n",
        args.roots
            .iter()
            .map(|p| p.display().to_string())
            .collect::<Vec<_>>()
            .join(", "),
        baseline_path.display(),
        baseline.len(),
        if baseline.len() == 1 { "y" } else { "ies" },
    );
    let report = match args.format {
        Format::Text => render_report(&fresh, &baselined, &files_note),
        Format::Json => {
            // The JSON report carries every finding; baselined ones are
            // still distinguishable by re-checking against the baseline.
            let mut all: Vec<Finding> = Vec::with_capacity(fresh.len() + baselined.len());
            all.extend(fresh.iter().cloned());
            all.extend(baselined.iter().cloned());
            all.sort_by(|a, b| {
                (a.path.as_str(), a.line, a.col, a.rule)
                    .cmp(&(b.path.as_str(), b.line, b.col, b.rule))
            });
            report_json(&all)
        }
    };
    if let Some(path) = &args.report {
        std::fs::write(path, &report)
            .map_err(|e| format!("cannot write {}: {e}", path.display()))?;
    } else if args.format == Format::Json {
        println!("{report}");
    }

    if fresh.is_empty() {
        println!(
            "simlint: clean ({} baselined finding(s) suppressed)",
            baselined.len()
        );
        Ok(ExitCode::SUCCESS)
    } else {
        for f in &fresh {
            eprintln!("{}", f.render());
        }
        eprintln!(
            "simlint: {} gating finding(s) — fix, justify inline, or --update-baseline",
            fresh.len()
        );
        Ok(ExitCode::FAILURE)
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("simlint: error: {e}");
            ExitCode::from(2)
        }
    }
}
