//! MoE serving support (§II-C): the expert router (gate mimic), expert-
//! parallel dispatch accounting, and expert-offloading engines.
//!
//! The expert router mimics the statistics of a real gate function: per
//! token it draws `top_k` distinct experts from a configurable popularity
//! distribution (uniform, or Zipf-skewed — real gates are heavily skewed).
//! The resulting per-expert token counts drive (a) expert-FFN pricing, (b)
//! the all-to-all skew factor for the EP fabric, and (c) which experts an
//! offloading engine must fetch.

use crate::config::{GateKind, OffloadPolicy};
use crate::model::ModelSpec;
use crate::perf::HardwareSpec;
use crate::sim::Nanos;
use crate::util::rng::{Rng, ZipfTable};

/// Per-layer outcome of routing `tokens` tokens through the gate.
#[derive(Debug, Clone)]
pub struct GateOutcome {
    /// tokens routed to each expert (length = experts); sums to
    /// `tokens * top_k`.
    pub tokens_per_expert: Vec<u64>,
}

impl GateOutcome {
    /// Number of experts that received at least one token.
    pub fn active_experts(&self) -> usize {
        self.tokens_per_expert.iter().filter(|&&t| t > 0).count()
    }

    /// Skew = max / mean over ACTIVE experts (>= 1.0); drives all-to-all
    /// congestion modeling.
    pub fn skew(&self) -> f64 {
        let (mut max, mut sum, mut n) = (0u64, 0u64, 0u64);
        for &t in self.tokens_per_expert.iter().filter(|&&t| t > 0) {
            max = max.max(t);
            sum += t;
            n += 1;
        }
        if n == 0 {
            return 1.0;
        }
        let mean = sum as f64 / n as f64;
        (max as f64 / mean).max(1.0)
    }
}

/// Expert router: mimics a trained gate's routing statistics.
#[derive(Debug)]
pub struct ExpertRouter {
    experts: usize,
    top_k: usize,
    kind: GateKind,
    zipf: Option<ZipfTable>,
    /// Per-expert popularity ranking permutation so the "hot" expert is not
    /// always index 0 across layers (layer-dependent remap).
    layer_perm: Vec<Vec<usize>>,
    rng: Rng,
}

impl ExpertRouter {
    pub fn new(model: &ModelSpec, kind: GateKind, layers: u64, seed: u64) -> Self {
        let experts = model.experts as usize;
        let top_k = model.top_k as usize;
        assert!(experts > 0 && top_k > 0, "expert router needs a MoE model");
        let zipf = match kind {
            GateKind::Zipf { s } => Some(ZipfTable::new(experts, s)),
            GateKind::Uniform => None,
        };
        let mut rng = Rng::new(seed ^ 0xE0E0_E0E0);
        let layer_perm = (0..layers)
            .map(|_| {
                let mut p: Vec<usize> = (0..experts).collect();
                rng.shuffle(&mut p);
                p
            })
            .collect();
        ExpertRouter {
            experts,
            top_k,
            kind,
            zipf,
            layer_perm,
            rng,
        }
    }

    /// Route `tokens` tokens at `layer`; returns per-expert token counts.
    ///
    /// Sampling is per-token without replacement within a token's top-k set,
    /// mirroring how a softmax gate picks k distinct experts.
    pub fn route(&mut self, layer: u64, tokens: u64) -> GateOutcome {
        // simlint: allow(H01) — the per-expert counts ARE the returned
        // outcome (`experts` elements, tens); a scratch buffer would force
        // a clone into GateOutcome and save nothing
        let mut counts = vec![0u64; self.experts];
        let perm = &self.layer_perm[(layer as usize) % self.layer_perm.len()];
        for _ in 0..tokens {
            let mut chosen = [usize::MAX; 8];
            let mut n = 0;
            while n < self.top_k {
                let raw = match (&self.kind, &self.zipf) {
                    (GateKind::Uniform, _) => self.rng.below(self.experts as u64) as usize,
                    (GateKind::Zipf { .. }, Some(z)) => z.sample(&mut self.rng),
                    _ => unreachable!(),
                };
                let e = perm[raw];
                if !chosen[..n].contains(&e) {
                    chosen[n] = e;
                    n += 1;
                    counts[e] += 1;
                }
            }
        }
        GateOutcome {
            tokens_per_expert: counts,
        }
    }
}

/// Outcome of an offloading decision for one MoE layer invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadCost {
    /// Extra latency exposed on the critical path, ns.
    pub exposed_ns: Nanos,
    /// Bytes moved over the host link.
    pub bytes_moved: u64,
    /// If true, expert FFN compute runs on the offload device (PIM) and
    /// must be priced with the PIM hardware instead of the local device.
    pub compute_remote: bool,
}

/// Expert-offloading engine: prices the weight movement (or remote compute)
/// for the experts a layer needs.
#[derive(Debug, Clone)]
pub struct OffloadEngine {
    pub policy: OffloadPolicy,
    /// Fraction of each layer's experts resident in device memory, derived
    /// from the memory budget left after weights + KV allocation.
    pub resident_fraction: f64,
    /// Prefetch misprediction rate (pre-gated MoE is imperfect; ~10% of
    /// fetches are late).
    pub mispredict: f64,
    pub expert_bytes: u64,
    pub host_bw: f64,
}

impl OffloadEngine {
    pub fn new(
        policy: OffloadPolicy,
        model: &ModelSpec,
        hw: &HardwareSpec,
        kv_budget_bytes: u64,
    ) -> Self {
        let expert_bytes = model.expert_bytes();
        let resident_fraction = if policy == OffloadPolicy::None {
            1.0
        } else {
            // Memory left for experts after parameters-excluding-experts + KV.
            let expert_total = model.moe_layers() * model.experts * expert_bytes;
            let non_expert = model.param_bytes().saturating_sub(expert_total);
            let left = hw
                .mem_capacity
                .saturating_sub(non_expert)
                .saturating_sub(kv_budget_bytes);
            (left as f64 / expert_total.max(1) as f64).clamp(0.0, 1.0)
        };
        OffloadEngine {
            policy,
            resident_fraction,
            mispredict: 0.1,
            expert_bytes,
            host_bw: hw.host_bw,
        }
    }

    /// Cost of making `needed` experts available for one layer, given
    /// `layer_compute_ns` of overlappable compute in the same layer.
    pub fn layer_cost(&self, needed: usize, layer_compute_ns: Nanos) -> OffloadCost {
        let missing = ((needed as f64) * (1.0 - self.resident_fraction)).round() as u64;
        match self.policy {
            OffloadPolicy::None => OffloadCost {
                exposed_ns: 0,
                bytes_moved: 0,
                compute_remote: false,
            },
            OffloadPolicy::OnDemand => {
                let bytes = missing * self.expert_bytes;
                OffloadCost {
                    exposed_ns: (bytes as f64 / self.host_bw * 1e9).round() as Nanos,
                    bytes_moved: bytes,
                    compute_remote: false,
                }
            }
            OffloadPolicy::Prefetch => {
                let bytes = missing * self.expert_bytes;
                let fetch = (bytes as f64 / self.host_bw * 1e9).round() as Nanos;
                // Fetch overlaps the previous layer's compute; only the
                // overflow plus mispredicted (late) fetches are exposed.
                let overflow = fetch.saturating_sub(layer_compute_ns);
                let late = (fetch as f64 * self.mispredict).round() as Nanos;
                OffloadCost {
                    exposed_ns: overflow + late,
                    bytes_moved: bytes,
                    compute_remote: false,
                }
            }
            OffloadPolicy::Pim => {
                // Experts live (and execute) in the PIM device; instead of
                // weights, the layer's activations cross the host link.
                OffloadCost {
                    exposed_ns: 0, // transfer priced by caller from bytes
                    bytes_moved: 0,
                    compute_remote: true,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn router(kind: GateKind) -> ExpertRouter {
        ExpertRouter::new(&ModelSpec::tiny_moe(), kind, 4, 42)
    }

    #[test]
    fn routes_conserve_tokens() {
        let mut r = router(GateKind::Uniform);
        let out = r.route(0, 100);
        assert_eq!(out.tokens_per_expert.iter().sum::<u64>(), 200); // top_k=2
        assert_eq!(out.tokens_per_expert.len(), 8);
    }

    #[test]
    fn zipf_gate_is_skewed_uniform_is_not() {
        let mut ru = router(GateKind::Uniform);
        let mut rz = router(GateKind::Zipf { s: 1.5 });
        let (mut su, mut sz) = (0.0, 0.0);
        for layer in 0..4 {
            su += ru.route(layer, 500).skew();
            sz += rz.route(layer, 500).skew();
        }
        assert!(
            sz / 4.0 > su / 4.0 + 0.3,
            "zipf skew {} vs uniform {}",
            sz / 4.0,
            su / 4.0
        );
    }

    #[test]
    fn hot_expert_varies_by_layer() {
        let mut r = router(GateKind::Zipf { s: 1.5 });
        let hot: Vec<usize> = (0..4)
            .map(|l| {
                let out = r.route(l, 2000);
                out.tokens_per_expert
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &t)| t)
                    .unwrap()
                    .0
            })
            .collect();
        // with 4 layers and 8 experts, all-identical hot experts would mean
        // the permutation is broken
        assert!(hot.windows(2).any(|w| w[0] != w[1]), "hot={hot:?}");
    }

    #[test]
    fn prop_topk_bounds_per_expert() {
        prop::check(
            "gate-topk-bounds",
            32,
            |rng| (1 + rng.below(200), rng.below(2) == 0),
            |&(tokens, uniform)| {
                let kind = if uniform {
                    GateKind::Uniform
                } else {
                    GateKind::Zipf { s: 1.0 }
                };
                let mut r = router(kind);
                let out = r.route(0, tokens);
                // no expert can receive more than `tokens` (distinct per token)
                if out.tokens_per_expert.iter().any(|&t| t > tokens) {
                    return Err(format!("expert over-assigned: {out:?}"));
                }
                if out.tokens_per_expert.iter().sum::<u64>() != tokens * 2 {
                    return Err("token conservation violated".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn offload_none_is_free() {
        let model = ModelSpec::tiny_moe();
        let hw = HardwareSpec::rtx3090();
        let e = OffloadEngine::new(OffloadPolicy::None, &model, &hw, 0);
        assert_eq!(e.resident_fraction, 1.0);
        let c = e.layer_cost(8, 1_000_000);
        assert_eq!(c.exposed_ns, 0);
        assert_eq!(c.bytes_moved, 0);
    }

    #[test]
    fn on_demand_blocks_prefetch_overlaps() {
        let model = ModelSpec::tiny_moe();
        let mut hw = HardwareSpec::rtx3090();
        // Memory so tight that only ~half the experts fit.
        let expert_total = model.moe_layers() * model.experts * model.expert_bytes();
        hw.mem_capacity = model.param_bytes() - expert_total / 2;
        let od = OffloadEngine::new(OffloadPolicy::OnDemand, &model, &hw, 0);
        let pf = OffloadEngine::new(OffloadPolicy::Prefetch, &model, &hw, 0);
        assert!(od.resident_fraction < 0.75);
        let big_compute = 10_000_000; // 10 ms of overlap available
        let c_od = od.layer_cost(8, big_compute);
        let c_pf = pf.layer_cost(8, big_compute);
        assert!(c_od.exposed_ns > 0);
        assert!(
            c_pf.exposed_ns < c_od.exposed_ns,
            "prefetch {} !< on-demand {}",
            c_pf.exposed_ns,
            c_od.exposed_ns
        );
        assert_eq!(c_od.bytes_moved, c_pf.bytes_moved);
    }

    #[test]
    fn pim_moves_compute_not_weights() {
        let model = ModelSpec::tiny_moe();
        let hw = HardwareSpec::rtx3090();
        let e = OffloadEngine::new(OffloadPolicy::Pim, &model, &hw, 0);
        let c = e.layer_cost(8, 0);
        assert!(c.compute_remote);
        assert_eq!(c.bytes_moved, 0);
    }

    #[test]
    fn resident_fraction_full_when_memory_ample() {
        let model = ModelSpec::tiny_moe();
        let hw = HardwareSpec::rtx3090(); // 24 GB vs tiny model
        let e = OffloadEngine::new(OffloadPolicy::OnDemand, &model, &hw, 0);
        assert_eq!(e.resident_fraction, 1.0);
        assert_eq!(e.layer_cost(8, 0).exposed_ns, 0);
    }
}
