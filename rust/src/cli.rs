//! Minimal argument parser (clap is unavailable offline).
//!
//! Grammar: `llmservingsim <command> [--flag value]... [--switch]...`
//! Flags may appear in any order; unknown flags are errors. Values are
//! fetched typed with defaults.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        known_switches: &[&str],
    ) -> anyhow::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        let mut switches = vec![];
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                anyhow::bail!("unexpected positional argument '{arg}'");
            };
            if known_switches.contains(&name) {
                switches.push(name.to_string());
            } else {
                let value = it
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?;
                flags.insert(name.to_string(), value);
            }
        }
        Ok(Args {
            command,
            flags,
            switches,
        })
    }

    pub fn str_flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str_flag(name).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = Args::parse(
            argv("simulate --preset S(D) --requests 50 --quick"),
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.str_flag("preset"), Some("S(D)"));
        assert_eq!(a.u64_or("requests", 100).unwrap(), 50);
        assert!(a.switch("quick"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(argv("profile"), &[]).unwrap();
        assert_eq!(a.u64_or("reps", 7).unwrap(), 7);
        assert_eq!(a.str_or("model", "tiny-dense"), "tiny-dense");
        assert!((a.f64_or("rate", 10.0).unwrap() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("run --out"), &[]).is_err());
    }

    #[test]
    fn positional_is_error() {
        assert!(Args::parse(argv("run stray"), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv("run --n abc"), &[]).unwrap();
        assert!(a.u64_or("n", 1).is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(Vec::<String>::new(), &[]).unwrap();
        assert_eq!(a.command, "help");
    }
}
