//! Simulation configuration: the JSON schema users write, plus built-in
//! presets for the paper's Table II serving configurations.
//!
//! A [`SimConfig`] fully determines a simulation (given a trace DB for the
//! trace-driven backend): instances with per-instance hardware/model/
//! parallelism/policies, the global router policy, the workload, and the
//! performance backend. Everything is plain data here; the serving layer
//! interprets it.
//!
//! Routing, scheduling, and eviction policies are stored as *names*
//! (plain strings, e.g. `"least-outstanding"`, `"fcfs"`, `"lru"`), so the
//! JSON schema is stable and user-registered policies are configurable
//! without touching this module. Names resolve against the
//! [`policy registry`](crate::policy) exactly once, when a
//! [`Simulation`](crate::coordinator::Simulation) is built — unknown names
//! error there with the list of registered candidates.

pub mod presets;

use crate::model::ModelSpec;
use crate::perf::HardwareSpec;
use crate::util::json::{self, Value};
use crate::workload::{
    Arrival, LengthDist, SloClass, TenantSpec, Traffic, WorkloadSpec,
};

/// Instance role in a (possibly P/D-disaggregated) deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Runs both prefill and decode (non-disaggregated).
    Unified,
    /// Prefill-only instance; hands off KV to a decode instance.
    Prefill,
    /// Decode-only instance; receives KV from prefill instances.
    Decode,
}

impl std::str::FromStr for Role {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Role, Self::Err> {
        Ok(match s {
            "unified" => Role::Unified,
            "prefill" => Role::Prefill,
            "decode" => Role::Decode,
            _ => anyhow::bail!("unknown role '{s}' (unified|prefill|decode)"),
        })
    }
}

impl Role {
    pub fn as_str(self) -> &'static str {
        match self {
            Role::Unified => "unified",
            Role::Prefill => "prefill",
            Role::Decode => "decode",
        }
    }
}

/// Typed handle for the built-in batch-scheduling policies.
///
/// Configs store scheduling policies by *name* ([`InstanceConfig::sched`]);
/// this enum is the convenience bridge for code that wants a `Copy` value
/// (tests, ablations) — `as_str()` is the registry name and `to_policy()`
/// instantiates the matching [`SchedulePolicy`](crate::policy::SchedulePolicy)
/// trait object.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// First-come-first-served admission (vLLM default).
    Fcfs,
    /// Shortest prompt first.
    Sjf,
    /// Priority = waiting time (anti-starvation SJF hybrid).
    Priority,
    /// Earliest TTFT deadline first, derived from each request's
    /// [`SloClass`](crate::workload::SloClass) (interactive traffic
    /// overtakes batch traffic until its deadline slack evens out).
    Slo,
}

impl std::str::FromStr for SchedPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SchedPolicy, Self::Err> {
        Ok(match s {
            "fcfs" => SchedPolicy::Fcfs,
            "sjf" => SchedPolicy::Sjf,
            "priority" => SchedPolicy::Priority,
            "slo" => SchedPolicy::Slo,
            _ => anyhow::bail!("unknown sched policy '{s}' (fcfs|sjf|priority|slo)"),
        })
    }
}

impl SchedPolicy {
    pub fn all() -> &'static [SchedPolicy] {
        &[
            SchedPolicy::Fcfs,
            SchedPolicy::Sjf,
            SchedPolicy::Priority,
            SchedPolicy::Slo,
        ]
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SchedPolicy::Fcfs => "fcfs",
            SchedPolicy::Sjf => "sjf",
            SchedPolicy::Priority => "priority",
            SchedPolicy::Slo => "slo",
        }
    }

    /// Instantiate the matching built-in trait object.
    pub fn to_policy(self) -> Box<dyn crate::policy::SchedulePolicy> {
        use crate::instance::scheduler::{Fcfs, Priority, Sjf, SloDeadline};
        match self {
            SchedPolicy::Fcfs => Box::new(Fcfs),
            SchedPolicy::Sjf => Box::new(Sjf),
            SchedPolicy::Priority => Box::new(Priority),
            SchedPolicy::Slo => Box::new(SloDeadline),
        }
    }
}

/// MoE gate-mimic distribution (§II-C expert router).
#[derive(Debug, Clone, PartialEq)]
pub enum GateKind {
    /// Tokens spread uniformly over experts.
    Uniform,
    /// Zipf-skewed expert popularity with exponent `s` (hot experts).
    Zipf { s: f64 },
}

/// Expert-offloading strategy (§II-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// All experts resident in device memory.
    None,
    /// Experts fetched from host on demand (blocking).
    OnDemand,
    /// Pre-gated prefetch: next layer's experts fetched during the current
    /// layer's compute; only mispredicted experts block.
    Prefetch,
    /// Experts execute in a PIM-like memory device; activations ship over
    /// the host link instead of weights.
    Pim,
}

impl std::str::FromStr for OffloadPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<OffloadPolicy, Self::Err> {
        Ok(match s {
            "none" => OffloadPolicy::None,
            "on-demand" => OffloadPolicy::OnDemand,
            "prefetch" => OffloadPolicy::Prefetch,
            "pim" => OffloadPolicy::Pim,
            _ => anyhow::bail!(
                "unknown offload policy '{s}' (none|on-demand|prefetch|pim)"
            ),
        })
    }
}

impl OffloadPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            OffloadPolicy::None => "none",
            OffloadPolicy::OnDemand => "on-demand",
            OffloadPolicy::Prefetch => "prefetch",
            OffloadPolicy::Pim => "pim",
        }
    }
}

/// KV-cache transfer policy for P/D disaggregation (§II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvTransferPolicy {
    /// Transfer the full KV cache after prefill completes, then decode.
    Blocking,
    /// Layer-by-layer transfer overlapped with prefill (Splitwise-style):
    /// only the last layer's KV transfer is exposed.
    Layered,
}

impl std::str::FromStr for KvTransferPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<KvTransferPolicy, Self::Err> {
        Ok(match s {
            "blocking" => KvTransferPolicy::Blocking,
            "layered" => KvTransferPolicy::Layered,
            _ => anyhow::bail!(
                "unknown kv-transfer policy '{s}' (blocking|layered)"
            ),
        })
    }
}

impl KvTransferPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            KvTransferPolicy::Blocking => "blocking",
            KvTransferPolicy::Layered => "layered",
        }
    }
}

/// Prefix-cache scope (§II-D: per-instance and global shared caches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheScope {
    PerInstance,
    Global,
}

/// One scripted fault for the `failure-replay` cluster controller.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureSpec {
    /// Instance index (in construction order) to fail.
    pub instance: usize,
    /// Failure time, milliseconds of simulated time.
    pub at_ms: u64,
    /// Optional recovery time (ms); the instance warms up and rejoins.
    pub recover_ms: Option<u64>,
}

/// Seeded fault-injection profile for the `chaos` cluster controller.
///
/// A profile is a distribution over fault *incidents*: plain instance
/// crashes, correlated zone outages (optionally with a fabric partition),
/// stragglers (slow-but-alive instances), and link degradations. All
/// randomness flows through [`crate::util::rng`] seeded from `seed`, so a
/// profile replays byte-identically. The default profile is **inert**
/// (`fault_rate == 0`) and a chaos controller running it is byte-identical
/// to no controller at all.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// Mean fault incidents per simulated second (Poisson process).
    /// `0.0` disables injection entirely.
    pub fault_rate: f64,
    /// Probability an incident takes out the victim's whole zone
    /// (correlated failure domain) instead of one instance.
    pub domain_correlation: f64,
    /// Probability a zone outage also partitions the zone off the
    /// inter-instance fabric (in-flight handoffs must re-route or park).
    pub partition_prob: f64,
    /// Probability an incident manifests as a straggler (perf multiplier)
    /// instead of a crash.
    pub straggler_prob: f64,
    /// Step-latency multiplier applied to straggler victims (>= 1).
    pub straggler_scale: f64,
    /// Probability an incident manifests as fabric-link degradation on the
    /// victim instance's links.
    pub link_degrade_prob: f64,
    /// Bandwidth multiplier for degraded links, in (0, 1].
    pub link_scale: f64,
    /// Median time-to-recovery, milliseconds (lognormal).
    pub mttr_ms: u64,
    /// Lognormal sigma of the recovery time.
    pub mttr_sigma: f64,
    /// Injection horizon, ms of simulated time (`0` = whole run).
    pub horizon_ms: u64,
    /// Chaos RNG seed (independent of the workload seed).
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            fault_rate: 0.0,
            domain_correlation: 0.25,
            partition_prob: 0.0,
            straggler_prob: 0.2,
            straggler_scale: 2.5,
            link_degrade_prob: 0.2,
            link_scale: 0.25,
            mttr_ms: 400,
            mttr_sigma: 0.25,
            horizon_ms: 0,
            seed: 0xC4A05,
        }
    }
}

impl ChaosConfig {
    /// Whether this profile injects anything at all.
    pub fn enabled(&self) -> bool {
        self.fault_rate > 0.0
    }

    /// Built-in named profiles for the CLI/sweep `--chaos` axis.
    pub fn profile_names() -> &'static [&'static str] {
        &["none", "light", "heavy", "partition"]
    }

    /// Resolve a named profile; errors list the candidates.
    pub fn profile(name: &str) -> anyhow::Result<ChaosConfig> {
        let base = ChaosConfig::default();
        Ok(match name {
            "none" => base,
            "light" => ChaosConfig {
                fault_rate: 0.5,
                domain_correlation: 0.1,
                partition_prob: 0.0,
                straggler_prob: 0.3,
                mttr_ms: 300,
                ..base
            },
            "heavy" => ChaosConfig {
                fault_rate: 2.0,
                domain_correlation: 0.4,
                partition_prob: 0.2,
                straggler_prob: 0.25,
                link_degrade_prob: 0.25,
                mttr_ms: 500,
                mttr_sigma: 0.5,
                ..base
            },
            "partition" => ChaosConfig {
                fault_rate: 1.0,
                domain_correlation: 1.0,
                partition_prob: 1.0,
                straggler_prob: 0.0,
                link_degrade_prob: 0.0,
                ..base
            },
            _ => anyhow::bail!(
                "unknown chaos profile '{name}' (candidates: {})",
                Self::profile_names().join(", ")
            ),
        })
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for (field, v) in [
            ("fault_rate", self.fault_rate),
            ("domain_correlation", self.domain_correlation),
            ("partition_prob", self.partition_prob),
            ("straggler_prob", self.straggler_prob),
            ("link_degrade_prob", self.link_degrade_prob),
        ] {
            if !(v >= 0.0) || !v.is_finite() {
                anyhow::bail!("cluster.chaos.{field} must be finite and >= 0");
            }
        }
        for (field, v) in [
            ("domain_correlation", self.domain_correlation),
            ("partition_prob", self.partition_prob),
            ("straggler_prob", self.straggler_prob),
            ("link_degrade_prob", self.link_degrade_prob),
        ] {
            if v > 1.0 {
                anyhow::bail!("cluster.chaos.{field} must be <= 1");
            }
        }
        if self.enabled() && self.mttr_ms == 0 {
            anyhow::bail!("cluster.chaos.mttr_ms must be > 0 when faults are on");
        }
        if self.straggler_scale < 1.0 {
            anyhow::bail!("cluster.chaos.straggler_scale must be >= 1");
        }
        if !(self.link_scale > 0.0 && self.link_scale <= 1.0) {
            anyhow::bail!("cluster.chaos.link_scale must be in (0, 1]");
        }
        if !(self.mttr_sigma >= 0.0) || !self.mttr_sigma.is_finite() {
            anyhow::bail!("cluster.chaos.mttr_sigma must be finite and >= 0");
        }
        Ok(())
    }
}

/// Admission control on the coordinator's arrival path: a token-bucket
/// rate limit plus a queue-depth circuit breaker. Rejected requests are a
/// terminal outcome recorded in the report (never silently dropped), so
/// `rejected + finished + in-flight == arrivals` always holds.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// Sustained admission rate, requests/second (token-bucket refill).
    pub rate: f64,
    /// Bucket capacity: how many requests a burst can admit at once.
    pub burst: f64,
    /// Circuit breaker: trip when total fleet wait-queue depth exceeds
    /// this (`0` disables the breaker).
    pub breaker_queue: usize,
    /// Breaker cooldown: reject everything for this long after tripping.
    pub breaker_cooldown_ms: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            rate: 100.0,
            burst: 20.0,
            breaker_queue: 0,
            breaker_cooldown_ms: 500,
        }
    }
}

impl AdmissionConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if !(self.rate > 0.0) || !self.rate.is_finite() {
            anyhow::bail!("cluster.admission.rate must be finite and > 0");
        }
        if !(self.burst >= 1.0) || !self.burst.is_finite() {
            anyhow::bail!("cluster.admission.burst must be finite and >= 1");
        }
        Ok(())
    }
}

/// Cluster-dynamics settings: which
/// [`ClusterController`](crate::cluster::ClusterController) runs, its
/// tick cadence, fleet bounds, and controller-specific parameters.
///
/// The controller is stored as a *name* resolved through the
/// [`policy registry`](crate::policy), like every other plugin axis. The
/// default, `"static"`, schedules no ticks and takes no actions — runs are
/// byte-identical to a simulator without cluster dynamics.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Controller *name* (built-ins: `static`, `queue-threshold`,
    /// `failure-replay`, `chaos`).
    pub controller: String,
    /// Controller tick period, milliseconds of simulated time.
    pub tick_ms: u64,
    /// Warmup before a scaled-up/recovered instance turns `Active`, ms.
    pub warmup_ms: u64,
    /// Autoscaler floor (active instances).
    pub min_instances: usize,
    /// Autoscaler ceiling (active + starting instances).
    pub max_instances: usize,
    /// `queue-threshold`: scale up above this average wait-queue depth
    /// per live instance.
    pub scale_up_queue: f64,
    /// `queue-threshold`: scale down below this average depth.
    pub scale_down_queue: f64,
    /// `failure-replay`: the fault script.
    pub failures: Vec<FailureSpec>,
    /// `chaos`: the fault-injection profile (inert by default).
    pub chaos: ChaosConfig,
    /// Admission control on arrivals (`None` = admit everything).
    pub admission: Option<AdmissionConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            controller: "static".to_string(),
            tick_ms: 200,
            warmup_ms: 500,
            min_instances: 1,
            max_instances: 8,
            scale_up_queue: 8.0,
            scale_down_queue: 1.0,
            failures: vec![],
            chaos: ChaosConfig::default(),
            admission: None,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.tick_ms == 0 {
            anyhow::bail!("cluster.tick_ms must be > 0");
        }
        if self.min_instances == 0 {
            anyhow::bail!("cluster.min_instances must be >= 1");
        }
        if self.max_instances < self.min_instances {
            anyhow::bail!(
                "cluster.max_instances ({}) must be >= min_instances ({})",
                self.max_instances,
                self.min_instances
            );
        }
        if !(self.scale_up_queue > self.scale_down_queue && self.scale_down_queue >= 0.0)
        {
            anyhow::bail!(
                "cluster thresholds must satisfy scale_up_queue ({}) > \
                 scale_down_queue ({}) >= 0",
                self.scale_up_queue,
                self.scale_down_queue
            );
        }
        self.chaos.validate()?;
        if let Some(a) = &self.admission {
            a.validate()?;
        }
        Ok(())
    }
}

/// Prefix-cache settings.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixCacheConfig {
    /// Device-tier capacity as a fraction of KV memory (0..1].
    pub device_fraction: f64,
    /// Host-tier capacity in tokens.
    pub host_tokens: u64,
    /// Eviction-policy *name*, resolved through the
    /// [`policy registry`](crate::policy) (built-ins: `lru`, `lfu`,
    /// `largest`).
    pub policy: String,
    pub scope: CacheScope,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig {
            device_fraction: 0.2,
            host_tokens: 1 << 20,
            policy: "lru".to_string(),
            scope: CacheScope::PerInstance,
        }
    }
}

/// Interconnect topology kind for an instance's device fabric.
#[derive(Debug, Clone, PartialEq)]
pub enum TopoKind {
    FullyConnected,
    Ring,
    Switched,
    Hierarchical { nodes: usize, per_node: usize },
}

/// One serving instance.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceConfig {
    pub name: String,
    /// Model preset name (see [`ModelSpec::preset_names`]).
    pub model: String,
    /// Hardware name: a built-in preset ([`HardwareSpec::preset_names`])
    /// or any bundle registered in the
    /// [`hardware registry`](crate::perf::hardware).
    pub hardware: String,
    /// Devices in this instance.
    pub devices: usize,
    /// Tensor parallel degree (must divide `devices`).
    pub tp: usize,
    /// Pipeline parallel degree (`tp * pp * ep_groups == devices`).
    pub pp: usize,
    /// Expert parallel degree (MoE only; 1 = experts replicated).
    pub ep: usize,
    pub role: Role,
    /// Failure domain (rack/zone) label for correlated chaos faults.
    /// Instances sharing a zone fail together under
    /// [`ClusterAction::FailDomain`](crate::cluster::ClusterAction).
    pub zone: String,
    pub topology: TopoKind,
    /// Device-memory capacity override, bytes.
    pub mem_capacity: Option<u64>,
    /// Device-memory bandwidth override, bytes/s.
    pub mem_bw: Option<f64>,
    /// Continuous-batching token budget per step.
    pub max_batch_tokens: u64,
    /// Max sequences resident in a batch.
    pub max_batch_seqs: usize,
    /// Chunked-prefill chunk size; None = whole-prompt prefill.
    pub chunked_prefill: Option<u64>,
    /// Batch-scheduling policy *name*, resolved through the
    /// [`policy registry`](crate::policy) (built-ins: `fcfs`, `sjf`,
    /// `priority`).
    pub sched: String,
    pub prefix_cache: Option<PrefixCacheConfig>,
    pub gate: GateKind,
    pub offload: OffloadPolicy,
    pub kv_transfer: KvTransferPolicy,
    /// Attention/FFN disaggregation (Table I "AF"): attention ops execute
    /// on a memory-optimized device (PIM-like), FFN stays local; per-layer
    /// activation hops cross the host link.
    pub af_disagg: bool,
}

impl InstanceConfig {
    /// A reasonable single-device instance running `model` on `hardware`.
    pub fn basic(name: &str, model: &str, hardware: &str) -> InstanceConfig {
        InstanceConfig {
            name: name.into(),
            model: model.into(),
            hardware: hardware.into(),
            devices: 1,
            tp: 1,
            pp: 1,
            ep: 1,
            role: Role::Unified,
            zone: "default".to_string(),
            topology: TopoKind::FullyConnected,
            mem_capacity: None,
            mem_bw: None,
            max_batch_tokens: 2048,
            max_batch_seqs: 64,
            chunked_prefill: None,
            sched: "fcfs".to_string(),
            prefix_cache: None,
            gate: GateKind::Uniform,
            offload: OffloadPolicy::None,
            kv_transfer: KvTransferPolicy::Blocking,
            af_disagg: false,
        }
    }

    /// Resolve the model preset.
    pub fn model_spec(&self) -> anyhow::Result<ModelSpec> {
        ModelSpec::preset(&self.model)
            .ok_or_else(|| anyhow::anyhow!("unknown model preset '{}'", self.model))
    }

    /// Resolve hardware with overrides applied. Names resolve through the
    /// global [`hardware registry`](crate::perf::hardware) — built-in
    /// presets plus registered bundles — so a freshly imported device works
    /// here with zero config-schema changes; unknown names error with the
    /// candidate list.
    pub fn hardware_spec(&self) -> anyhow::Result<HardwareSpec> {
        let mut hw = HardwareSpec::resolve(&self.hardware).map_err(|e| {
            anyhow::anyhow!("instance '{}': {e}", self.name)
        })?;
        if let Some(c) = self.mem_capacity {
            hw.mem_capacity = c;
        }
        if let Some(b) = self.mem_bw {
            hw.mem_bw = b;
        }
        Ok(hw)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let model = self.model_spec()?;
        self.hardware_spec()?;
        if self.devices == 0 {
            anyhow::bail!("instance '{}': devices must be > 0", self.name);
        }
        if self.tp * self.pp == 0 || self.devices % (self.tp * self.pp) != 0 {
            anyhow::bail!(
                "instance '{}': tp({}) * pp({}) must divide devices({})",
                self.name,
                self.tp,
                self.pp,
                self.devices
            );
        }
        if self.ep > 1 {
            if !model.is_moe() {
                anyhow::bail!(
                    "instance '{}': ep > 1 requires a MoE model",
                    self.name
                );
            }
            if model.experts % self.ep as u64 != 0 {
                anyhow::bail!(
                    "instance '{}': ep({}) must divide experts({})",
                    self.name,
                    self.ep,
                    model.experts
                );
            }
        }
        if self.offload != OffloadPolicy::None && !model.is_moe() {
            anyhow::bail!(
                "instance '{}': expert offloading requires a MoE model",
                self.name
            );
        }
        if self.max_batch_tokens == 0 || self.max_batch_seqs == 0 {
            anyhow::bail!("instance '{}': batch limits must be > 0", self.name);
        }
        if let Some(pc) = &self.prefix_cache {
            if !(0.0 < pc.device_fraction && pc.device_fraction <= 1.0) {
                anyhow::bail!(
                    "instance '{}': prefix-cache device_fraction must be in (0,1]",
                    self.name
                );
            }
        }
        Ok(())
    }
}

/// Performance-model backend selection (§III simulator baselines).
#[derive(Debug, Clone, PartialEq)]
pub enum PerfBackend {
    /// Trace-driven (LLMServingSim2.0): profiled-trace DB from `path`,
    /// calibrated-analytical extension for unprofiled model configs.
    Trace { path: String },
    /// Pure roofline.
    Analytical,
    /// Cycle-level systolic NPU simulation (LLMServingSim 1.0 baseline).
    Cycle,
    /// Cycle simulation with memoized replay (LLMServingSim+ baseline).
    CycleReplay,
}

impl std::str::FromStr for PerfBackend {
    type Err = anyhow::Error;

    /// Parse the CLI spelling: `analytical`, `cycle`, `cycle-replay`, or
    /// `trace:PATH`.
    fn from_str(s: &str) -> Result<PerfBackend, Self::Err> {
        Ok(match s {
            "analytical" => PerfBackend::Analytical,
            "cycle" => PerfBackend::Cycle,
            "cycle-replay" => PerfBackend::CycleReplay,
            _ => match s.strip_prefix("trace:") {
                Some(path) => PerfBackend::Trace {
                    path: path.to_string(),
                },
                None => anyhow::bail!(
                    "unknown perf backend '{s}' \
                     (analytical|cycle|cycle-replay|trace:PATH)"
                ),
            },
        })
    }
}

impl PerfBackend {
    /// The CLI spelling parsed by `FromStr` (round-trips).
    pub fn cli_str(&self) -> String {
        match self {
            PerfBackend::Analytical => "analytical".into(),
            PerfBackend::Cycle => "cycle".into(),
            PerfBackend::CycleReplay => "cycle-replay".into(),
            PerfBackend::Trace { path } => format!("trace:{path}"),
        }
    }
}

/// Displays as the CLI spelling, so `format!` sites and the manifest
/// codec round-trip through `FromStr` without a helper call.
impl std::fmt::Display for PerfBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.cli_str())
    }
}

/// Top-level simulation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub name: String,
    pub seed: u64,
    pub instances: Vec<InstanceConfig>,
    /// Global router-policy *name*, resolved through the
    /// [`policy registry`](crate::policy) (built-ins: `round-robin`,
    /// `least-outstanding`, `least-kv`, `prefix-aware`,
    /// `session-affinity`).
    pub router: String,
    pub workload: WorkloadSpec,
    pub perf: PerfBackend,
    /// KV block size in tokens (PagedAttention granularity).
    pub block_size: u64,
    /// Interconnect between instances (router fabric + P/D transfers).
    pub inter_instance_bw: f64,
    pub inter_instance_latency_ns: u64,
    /// Cluster-dynamics settings (controller name, tick, fleet bounds).
    pub cluster: ClusterConfig,
}

impl SimConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.instances.is_empty() {
            anyhow::bail!("config '{}': needs at least one instance", self.name);
        }
        for inst in &self.instances {
            inst.validate()?;
        }
        let has_prefill = self.instances.iter().any(|i| i.role == Role::Prefill);
        let has_decode = self.instances.iter().any(|i| i.role == Role::Decode);
        if has_prefill != has_decode {
            anyhow::bail!(
                "config '{}': P/D disaggregation needs both prefill and decode \
                 instances",
                self.name
            );
        }
        if self.block_size == 0 {
            anyhow::bail!("config '{}': block_size must be > 0", self.name);
        }
        self.cluster
            .validate()
            .map_err(|e| anyhow::anyhow!("config '{}': {e}", self.name))?;
        self.workload
            .validate()
            .map_err(|e| anyhow::anyhow!("config '{}': {e}", self.name))?;
        Ok(())
    }

    // ---- JSON ---------------------------------------------------------

    pub fn to_json(&self) -> Value {
        let insts = self
            .instances
            .iter()
            .map(|i| {
                let mut fields = vec![
                    ("name", Value::str(i.name.clone())),
                    ("model", Value::str(i.model.clone())),
                    ("hardware", Value::str(i.hardware.clone())),
                    ("devices", Value::int(i.devices as i64)),
                    ("tp", Value::int(i.tp as i64)),
                    ("pp", Value::int(i.pp as i64)),
                    ("ep", Value::int(i.ep as i64)),
                    ("role", Value::str(i.role.as_str())),
                    ("max_batch_tokens", Value::int(i.max_batch_tokens as i64)),
                    ("max_batch_seqs", Value::int(i.max_batch_seqs as i64)),
                    ("sched", Value::str(i.sched.clone())),
                    ("offload", Value::str(i.offload.as_str())),
                    ("kv_transfer", Value::str(i.kv_transfer.as_str())),
                    ("af_disagg", Value::Bool(i.af_disagg)),
                    (
                        "topology",
                        Value::str(match &i.topology {
                            TopoKind::FullyConnected => "fully-connected",
                            TopoKind::Ring => "ring",
                            TopoKind::Switched => "switched",
                            TopoKind::Hierarchical { .. } => "hierarchical",
                        }),
                    ),
                    (
                        "gate",
                        match &i.gate {
                            GateKind::Uniform => Value::str("uniform"),
                            GateKind::Zipf { s } => Value::obj(vec![
                                ("kind", Value::str("zipf")),
                                ("s", Value::float(*s)),
                            ]),
                        },
                    ),
                ];
                if i.zone != "default" {
                    fields.push(("zone", Value::str(i.zone.clone())));
                }
                if let Some(c) = i.mem_capacity {
                    fields.push(("mem_capacity", Value::int(c as i64)));
                }
                if let Some(b) = i.mem_bw {
                    fields.push(("mem_bw", Value::float(b)));
                }
                if let Some(cp) = i.chunked_prefill {
                    fields.push(("chunked_prefill", Value::int(cp as i64)));
                }
                if let Some(pc) = &i.prefix_cache {
                    fields.push((
                        "prefix_cache",
                        Value::obj(vec![
                            ("device_fraction", Value::float(pc.device_fraction)),
                            ("host_tokens", Value::int(pc.host_tokens as i64)),
                            ("policy", Value::str(pc.policy.clone())),
                            (
                                "scope",
                                Value::str(match pc.scope {
                                    CacheScope::PerInstance => "per-instance",
                                    CacheScope::Global => "global",
                                }),
                            ),
                        ]),
                    ));
                }
                Value::obj(fields)
            })
            .collect();
        Value::obj(vec![
            ("name", Value::str(self.name.clone())),
            ("seed", Value::int(self.seed as i64)),
            ("router", Value::str(self.router.clone())),
            ("block_size", Value::int(self.block_size as i64)),
            ("inter_instance_bw", Value::float(self.inter_instance_bw)),
            (
                "inter_instance_latency_ns",
                Value::int(self.inter_instance_latency_ns as i64),
            ),
            (
                "cluster",
                {
                    let mut fields = vec![
                        ("controller", Value::str(self.cluster.controller.clone())),
                        ("tick_ms", Value::int(self.cluster.tick_ms as i64)),
                        ("warmup_ms", Value::int(self.cluster.warmup_ms as i64)),
                        (
                            "min_instances",
                            Value::int(self.cluster.min_instances as i64),
                        ),
                        (
                            "max_instances",
                            Value::int(self.cluster.max_instances as i64),
                        ),
                        (
                            "scale_up_queue",
                            Value::float(self.cluster.scale_up_queue),
                        ),
                        (
                            "scale_down_queue",
                            Value::float(self.cluster.scale_down_queue),
                        ),
                        (
                            "failures",
                            Value::arr(
                                self.cluster
                                    .failures
                                    .iter()
                                    .map(|f| {
                                        let mut fields = vec![
                                            ("instance", Value::int(f.instance as i64)),
                                            ("at_ms", Value::int(f.at_ms as i64)),
                                        ];
                                        if let Some(r) = f.recover_ms {
                                            fields.push((
                                                "recover_ms",
                                                Value::int(r as i64),
                                            ));
                                        }
                                        Value::obj(fields)
                                    })
                                    .collect(),
                            ),
                        ),
                    ];
                    // Chaos/admission keys appear only when configured, so
                    // pre-chaos configs round-trip byte-identically.
                    let ch = &self.cluster.chaos;
                    if *ch != ChaosConfig::default() {
                        fields.push((
                            "chaos",
                            Value::obj(vec![
                                ("fault_rate", Value::float(ch.fault_rate)),
                                (
                                    "domain_correlation",
                                    Value::float(ch.domain_correlation),
                                ),
                                ("partition_prob", Value::float(ch.partition_prob)),
                                ("straggler_prob", Value::float(ch.straggler_prob)),
                                ("straggler_scale", Value::float(ch.straggler_scale)),
                                (
                                    "link_degrade_prob",
                                    Value::float(ch.link_degrade_prob),
                                ),
                                ("link_scale", Value::float(ch.link_scale)),
                                ("mttr_ms", Value::int(ch.mttr_ms as i64)),
                                ("mttr_sigma", Value::float(ch.mttr_sigma)),
                                ("horizon_ms", Value::int(ch.horizon_ms as i64)),
                                ("seed", Value::int(ch.seed as i64)),
                            ]),
                        ));
                    }
                    if let Some(a) = &self.cluster.admission {
                        fields.push((
                            "admission",
                            Value::obj(vec![
                                ("rate", Value::float(a.rate)),
                                ("burst", Value::float(a.burst)),
                                ("breaker_queue", Value::int(a.breaker_queue as i64)),
                                (
                                    "breaker_cooldown_ms",
                                    Value::int(a.breaker_cooldown_ms as i64),
                                ),
                            ]),
                        ));
                    }
                    Value::obj(fields)
                },
            ),
            (
                "perf",
                match &self.perf {
                    PerfBackend::Trace { path } => Value::obj(vec![
                        ("backend", Value::str("trace")),
                        ("path", Value::str(path.clone())),
                    ]),
                    PerfBackend::Analytical => {
                        Value::obj(vec![("backend", Value::str("analytical"))])
                    }
                    PerfBackend::Cycle => {
                        Value::obj(vec![("backend", Value::str("cycle"))])
                    }
                    PerfBackend::CycleReplay => {
                        Value::obj(vec![("backend", Value::str("cycle-replay"))])
                    }
                },
            ),
            (
                "workload",
                Value::obj(vec![
                    (
                        "num_requests",
                        Value::int(self.workload.num_requests as i64),
                    ),
                    ("traffic", traffic_to_json(&self.workload.traffic)),
                    (
                        "tenants",
                        Value::arr(
                            self.workload
                                .tenants
                                .iter()
                                .map(|t| {
                                    Value::obj(vec![
                                        ("name", Value::str(t.name.clone())),
                                        ("weight", Value::float(t.weight)),
                                        ("slo", Value::str(t.slo.as_str())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("sessions", Value::int(self.workload.sessions as i64)),
                    (
                        "shared_prefix",
                        Value::int(self.workload.shared_prefix as i64),
                    ),
                    ("seed", Value::int(self.workload.seed as i64)),
                    (
                        "lengths",
                        Value::obj(vec![
                            ("prompt_mu", Value::float(self.workload.lengths.prompt_mu)),
                            (
                                "prompt_sigma",
                                Value::float(self.workload.lengths.prompt_sigma),
                            ),
                            ("output_mu", Value::float(self.workload.lengths.output_mu)),
                            (
                                "output_sigma",
                                Value::float(self.workload.lengths.output_sigma),
                            ),
                            (
                                "min_tokens",
                                Value::int(self.workload.lengths.min_tokens as i64),
                            ),
                            (
                                "max_tokens",
                                Value::int(self.workload.lengths.max_tokens as i64),
                            ),
                        ]),
                    ),
                ]),
            ),
            ("instances", Value::Arr(insts)),
        ])
    }

    /// Parse a config written by [`SimConfig::to_json`]. Also accepts the
    /// pre-workload-engine schema where the workload carried a flat
    /// `arrival` object instead of `traffic`.
    pub fn from_json(v: &Value) -> anyhow::Result<SimConfig> {
        let name = v.get("name").as_str().unwrap_or("unnamed").to_string();
        let seed = v.get("seed").as_u64().unwrap_or(0);
        // Policy names are free-form here; they resolve (and error with
        // candidate lists) when the simulation is built.
        let router = v
            .get("router")
            .as_str()
            .unwrap_or("round-robin")
            .to_string();
        let block_size = v.get("block_size").as_u64().unwrap_or(16);
        let inter_instance_bw = v.get("inter_instance_bw").as_f64().unwrap_or(32e9);
        let inter_instance_latency_ns =
            v.get("inter_instance_latency_ns").as_u64().unwrap_or(5_000);

        let perf = {
            let p = v.get("perf");
            match p.get("backend").as_str().unwrap_or("analytical") {
                "trace" => PerfBackend::Trace {
                    path: p
                        .get("path")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("trace backend needs 'path'"))?
                        .to_string(),
                },
                "analytical" => PerfBackend::Analytical,
                "cycle" => PerfBackend::Cycle,
                "cycle-replay" => PerfBackend::CycleReplay,
                b => anyhow::bail!("unknown perf backend '{b}'"),
            }
        };

        // Cluster block: absent in pre-driver configs -> all defaults
        // (static controller, frozen fleet).
        let mut cluster = ClusterConfig::default();
        {
            let c = v.get("cluster");
            if let Some(s) = c.get("controller").as_str() {
                cluster.controller = s.to_string();
            }
            if let Some(x) = c.get("tick_ms").as_u64() {
                cluster.tick_ms = x;
            }
            if let Some(x) = c.get("warmup_ms").as_u64() {
                cluster.warmup_ms = x;
            }
            if let Some(x) = c.get("min_instances").as_u64() {
                cluster.min_instances = x as usize;
            }
            if let Some(x) = c.get("max_instances").as_u64() {
                cluster.max_instances = x as usize;
            }
            if let Some(x) = c.get("scale_up_queue").as_f64() {
                cluster.scale_up_queue = x;
            }
            if let Some(x) = c.get("scale_down_queue").as_f64() {
                cluster.scale_down_queue = x;
            }
            for fv in c.get("failures").as_arr().unwrap_or(&[]) {
                cluster.failures.push(FailureSpec {
                    instance: fv
                        .get("instance")
                        .as_u64()
                        .ok_or_else(|| {
                            anyhow::anyhow!("cluster failure missing 'instance'")
                        })? as usize,
                    at_ms: fv.get("at_ms").as_u64().ok_or_else(|| {
                        anyhow::anyhow!("cluster failure missing 'at_ms'")
                    })?,
                    recover_ms: fv.get("recover_ms").as_u64(),
                });
            }
            let ch = c.get("chaos");
            if !ch.is_null() {
                if let Some(x) = ch.get("fault_rate").as_f64() {
                    cluster.chaos.fault_rate = x;
                }
                if let Some(x) = ch.get("domain_correlation").as_f64() {
                    cluster.chaos.domain_correlation = x;
                }
                if let Some(x) = ch.get("partition_prob").as_f64() {
                    cluster.chaos.partition_prob = x;
                }
                if let Some(x) = ch.get("straggler_prob").as_f64() {
                    cluster.chaos.straggler_prob = x;
                }
                if let Some(x) = ch.get("straggler_scale").as_f64() {
                    cluster.chaos.straggler_scale = x;
                }
                if let Some(x) = ch.get("link_degrade_prob").as_f64() {
                    cluster.chaos.link_degrade_prob = x;
                }
                if let Some(x) = ch.get("link_scale").as_f64() {
                    cluster.chaos.link_scale = x;
                }
                if let Some(x) = ch.get("mttr_ms").as_u64() {
                    cluster.chaos.mttr_ms = x;
                }
                if let Some(x) = ch.get("mttr_sigma").as_f64() {
                    cluster.chaos.mttr_sigma = x;
                }
                if let Some(x) = ch.get("horizon_ms").as_u64() {
                    cluster.chaos.horizon_ms = x;
                }
                if let Some(x) = ch.get("seed").as_u64() {
                    cluster.chaos.seed = x;
                }
            }
            let ad = c.get("admission");
            if !ad.is_null() {
                let mut a = AdmissionConfig::default();
                if let Some(x) = ad.get("rate").as_f64() {
                    a.rate = x;
                }
                if let Some(x) = ad.get("burst").as_f64() {
                    a.burst = x;
                }
                if let Some(x) = ad.get("breaker_queue").as_u64() {
                    a.breaker_queue = x as usize;
                }
                if let Some(x) = ad.get("breaker_cooldown_ms").as_u64() {
                    a.breaker_cooldown_ms = x;
                }
                cluster.admission = Some(a);
            }
        }

        let w = v.get("workload");
        let traffic = if !w.get("traffic").is_null() {
            traffic_from_json(w.get("traffic"))?
        } else if !w.get("arrival").is_null() {
            // legacy schema: flat arrival object
            Traffic::Open(arrival_from_json(w.get("arrival"))?)
        } else {
            Traffic::poisson(10.0)
        };
        let mut tenants = vec![];
        for tv in w.get("tenants").as_arr().unwrap_or(&[]) {
            tenants.push(TenantSpec {
                name: tv
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("tenant missing 'name'"))?
                    .to_string(),
                weight: tv.get("weight").as_f64().unwrap_or(1.0),
                slo: match tv.get("slo").as_str() {
                    None => SloClass::Interactive,
                    Some(s) => s.parse::<SloClass>()?,
                },
            });
        }
        let l = w.get("lengths");
        let mut lengths = LengthDist::sharegpt();
        if let Some(x) = l.get("prompt_mu").as_f64() {
            lengths.prompt_mu = x;
        }
        if let Some(x) = l.get("prompt_sigma").as_f64() {
            lengths.prompt_sigma = x;
        }
        if let Some(x) = l.get("output_mu").as_f64() {
            lengths.output_mu = x;
        }
        if let Some(x) = l.get("output_sigma").as_f64() {
            lengths.output_sigma = x;
        }
        if let Some(x) = l.get("min_tokens").as_u64() {
            lengths.min_tokens = x;
        }
        if let Some(x) = l.get("max_tokens").as_u64() {
            lengths.max_tokens = x;
        }
        let workload = WorkloadSpec {
            num_requests: w.get("num_requests").as_u64().unwrap_or(100) as usize,
            traffic,
            lengths,
            sessions: w.get("sessions").as_u64().unwrap_or(0) as usize,
            shared_prefix: w.get("shared_prefix").as_u64().unwrap_or(0),
            tenants,
            seed: w.get("seed").as_u64().unwrap_or(0x5EED),
        };

        let mut instances = vec![];
        for iv in v.get("instances").as_arr().unwrap_or(&[]) {
            let mut inst = InstanceConfig::basic(
                iv.get("name").as_str().unwrap_or("inst"),
                iv.get("model")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("instance missing 'model'"))?,
                iv.get("hardware")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("instance missing 'hardware'"))?,
            );
            if let Some(x) = iv.get("devices").as_u64() {
                inst.devices = x as usize;
            }
            if let Some(x) = iv.get("tp").as_u64() {
                inst.tp = x as usize;
            }
            if let Some(x) = iv.get("pp").as_u64() {
                inst.pp = x as usize;
            }
            if let Some(x) = iv.get("ep").as_u64() {
                inst.ep = x as usize;
            }
            if let Some(s) = iv.get("role").as_str() {
                inst.role = s.parse::<Role>()?;
            }
            if let Some(s) = iv.get("zone").as_str() {
                inst.zone = s.to_string();
            }
            if let Some(s) = iv.get("sched").as_str() {
                inst.sched = s.to_string();
            }
            if let Some(s) = iv.get("offload").as_str() {
                inst.offload = s.parse::<OffloadPolicy>()?;
            }
            if let Some(s) = iv.get("kv_transfer").as_str() {
                inst.kv_transfer = s.parse::<KvTransferPolicy>()?;
            }
            if let Some(b) = iv.get("af_disagg").as_bool() {
                inst.af_disagg = b;
            }
            if let Some(s) = iv.get("topology").as_str() {
                inst.topology = match s {
                    "fully-connected" => TopoKind::FullyConnected,
                    "ring" => TopoKind::Ring,
                    "switched" => TopoKind::Switched,
                    "hierarchical" => TopoKind::Hierarchical {
                        nodes: iv.get("nodes").as_u64().unwrap_or(2) as usize,
                        per_node: iv.get("per_node").as_u64().unwrap_or(2) as usize,
                    },
                    _ => anyhow::bail!("unknown topology '{s}'"),
                };
            }
            let g = iv.get("gate");
            if let Some(s) = g.as_str() {
                inst.gate = match s {
                    "uniform" => GateKind::Uniform,
                    _ => anyhow::bail!("unknown gate '{s}'"),
                };
            } else if g.get("kind").as_str() == Some("zipf") {
                inst.gate = GateKind::Zipf {
                    s: g.get("s").as_f64().unwrap_or(1.0),
                };
            }
            if let Some(x) = iv.get("mem_capacity").as_u64() {
                inst.mem_capacity = Some(x);
            }
            if let Some(x) = iv.get("mem_bw").as_f64() {
                inst.mem_bw = Some(x);
            }
            if let Some(x) = iv.get("max_batch_tokens").as_u64() {
                inst.max_batch_tokens = x;
            }
            if let Some(x) = iv.get("max_batch_seqs").as_u64() {
                inst.max_batch_seqs = x as usize;
            }
            if let Some(x) = iv.get("chunked_prefill").as_u64() {
                inst.chunked_prefill = Some(x);
            }
            let pc = iv.get("prefix_cache");
            if !pc.is_null() {
                let mut cfg = PrefixCacheConfig::default();
                if let Some(x) = pc.get("device_fraction").as_f64() {
                    cfg.device_fraction = x;
                }
                if let Some(x) = pc.get("host_tokens").as_u64() {
                    cfg.host_tokens = x;
                }
                if let Some(s) = pc.get("policy").as_str() {
                    cfg.policy = s.to_string();
                }
                if let Some(s) = pc.get("scope").as_str() {
                    cfg.scope = match s {
                        "per-instance" => CacheScope::PerInstance,
                        "global" => CacheScope::Global,
                        _ => anyhow::bail!("unknown cache scope '{s}'"),
                    };
                }
                inst.prefix_cache = Some(cfg);
            }
            instances.push(inst);
        }

        let cfg = SimConfig {
            name,
            seed,
            instances,
            router,
            workload,
            perf,
            block_size,
            inter_instance_bw,
            inter_instance_latency_ns,
            cluster,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<SimConfig> {
        Self::from_json(&json::load_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        json::save_file(path, &self.to_json())
    }
}

// ---- traffic JSON (shared by the workload and legacy arrival schemas) ----

fn arrival_to_json(a: &Arrival) -> Value {
    match a {
        Arrival::Poisson { rate } => Value::obj(vec![
            ("kind", Value::str("poisson")),
            ("rate", Value::float(*rate)),
        ]),
        Arrival::Uniform { rate } => Value::obj(vec![
            ("kind", Value::str("uniform")),
            ("rate", Value::float(*rate)),
        ]),
        Arrival::Burst => Value::obj(vec![("kind", Value::str("burst"))]),
        Arrival::Mmpp {
            rate_on,
            rate_off,
            mean_on_s,
            mean_off_s,
        } => Value::obj(vec![
            ("kind", Value::str("mmpp")),
            ("rate_on", Value::float(*rate_on)),
            ("rate_off", Value::float(*rate_off)),
            ("mean_on_s", Value::float(*mean_on_s)),
            ("mean_off_s", Value::float(*mean_off_s)),
        ]),
        Arrival::Diurnal {
            base_rate,
            amplitude,
            period_s,
        } => Value::obj(vec![
            ("kind", Value::str("diurnal")),
            ("base_rate", Value::float(*base_rate)),
            ("amplitude", Value::float(*amplitude)),
            ("period_s", Value::float(*period_s)),
        ]),
    }
}

fn arrival_from_json(a: &Value) -> anyhow::Result<Arrival> {
    Ok(match a.get("kind").as_str().unwrap_or("poisson") {
        "poisson" => Arrival::Poisson {
            rate: a.get("rate").as_f64().unwrap_or(10.0),
        },
        "uniform" => Arrival::Uniform {
            rate: a.get("rate").as_f64().unwrap_or(10.0),
        },
        "burst" => Arrival::Burst,
        "mmpp" => Arrival::Mmpp {
            rate_on: a.get("rate_on").as_f64().unwrap_or(40.0),
            rate_off: a.get("rate_off").as_f64().unwrap_or(0.0),
            mean_on_s: a.get("mean_on_s").as_f64().unwrap_or(2.0),
            mean_off_s: a.get("mean_off_s").as_f64().unwrap_or(6.0),
        },
        "diurnal" => Arrival::Diurnal {
            base_rate: a.get("base_rate").as_f64().unwrap_or(10.0),
            amplitude: a.get("amplitude").as_f64().unwrap_or(0.8),
            period_s: a.get("period_s").as_f64().unwrap_or(60.0),
        },
        k => anyhow::bail!("unknown arrival kind '{k}'"),
    })
}

fn traffic_to_json(t: &Traffic) -> Value {
    match t {
        Traffic::Open(a) => arrival_to_json(a),
        Traffic::Sessions {
            start,
            turns,
            think_s,
        } => Value::obj(vec![
            ("kind", Value::str("sessions")),
            ("start", arrival_to_json(start)),
            ("turns", Value::int(*turns as i64)),
            ("think_s", Value::float(*think_s)),
        ]),
        Traffic::Replay { path } => Value::obj(vec![
            ("kind", Value::str("replay")),
            ("path", Value::str(path.clone())),
        ]),
        Traffic::Custom { name } => Value::obj(vec![
            ("kind", Value::str("custom")),
            ("name", Value::str(name.clone())),
        ]),
    }
}

fn traffic_from_json(t: &Value) -> anyhow::Result<Traffic> {
    Ok(match t.get("kind").as_str().unwrap_or("poisson") {
        "sessions" => Traffic::Sessions {
            start: if t.get("start").is_null() {
                Arrival::Poisson { rate: 2.0 }
            } else {
                arrival_from_json(t.get("start"))?
            },
            turns: t.get("turns").as_u64().unwrap_or(4) as u32,
            think_s: t.get("think_s").as_f64().unwrap_or(2.0),
        },
        "replay" => Traffic::Replay {
            path: t
                .get("path")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("replay traffic needs 'path'"))?
                .to_string(),
        },
        "custom" => Traffic::Custom {
            name: t
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("custom traffic needs 'name'"))?
                .to_string(),
        },
        _ => Traffic::Open(arrival_from_json(t)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_instance_validates() {
        let i = InstanceConfig::basic("a", "tiny-dense", "rtx3090");
        i.validate().unwrap();
    }

    #[test]
    fn tp_must_divide_devices() {
        let mut i = InstanceConfig::basic("a", "tiny-dense", "rtx3090");
        i.devices = 4;
        i.tp = 3;
        assert!(i.validate().is_err());
        i.tp = 2;
        i.pp = 2;
        i.validate().unwrap();
    }

    #[test]
    fn ep_requires_moe() {
        let mut i = InstanceConfig::basic("a", "tiny-dense", "rtx3090");
        i.devices = 2;
        i.ep = 2;
        assert!(i.validate().is_err());
        i.model = "tiny-moe".into();
        i.validate().unwrap();
    }

    #[test]
    fn offload_requires_moe() {
        let mut i = InstanceConfig::basic("a", "tiny-dense", "rtx3090");
        i.offload = OffloadPolicy::Prefetch;
        assert!(i.validate().is_err());
    }

    #[test]
    fn unknown_presets_rejected() {
        let i = InstanceConfig::basic("a", "bogus-model", "rtx3090");
        assert!(i.validate().is_err());
        let i = InstanceConfig::basic("a", "tiny-dense", "bogus-hw");
        assert!(i.validate().is_err());
    }

    #[test]
    fn unknown_hardware_errors_name_candidates() {
        // registry-backed resolution: the error names the instance, the bad
        // value, and the registered candidates (PR 2 policy-error style)
        let i = InstanceConfig::basic("inst7", "tiny-dense", "abacus");
        let e = i.hardware_spec().unwrap_err().to_string();
        assert!(e.contains("inst7"), "{e}");
        assert!(e.contains("abacus"), "{e}");
        assert!(e.contains("rtx3090") && e.contains("tpu-v6e"), "{e}");
    }

    #[test]
    fn overrides_apply() {
        let mut i = InstanceConfig::basic("a", "tiny-dense", "rtx3090");
        i.mem_capacity = Some(1 << 30);
        i.mem_bw = Some(1e11);
        let hw = i.hardware_spec().unwrap();
        assert_eq!(hw.mem_capacity, 1 << 30);
        assert_eq!(hw.mem_bw, 1e11);
    }

    #[test]
    fn pd_needs_both_roles() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.instances[0].role = Role::Prefill;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn json_roundtrip_all_presets() {
        for cfg in presets::all_table2("tiny-dense", "tiny-moe", "rtx3090") {
            cfg.validate().unwrap();
            let v = cfg.to_json();
            let back = SimConfig::from_json(&v).unwrap();
            assert_eq!(cfg, back, "roundtrip mismatch for {}", cfg.name);
        }
    }

    #[test]
    fn workload_traffic_and_tenants_roundtrip() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.workload.tenants = TenantSpec::mix(3);
        for traffic in [
            Traffic::uniform(5.0),
            Traffic::burst(),
            Traffic::mmpp(40.0, 1.0, 2.0, 6.0),
            Traffic::diurnal(10.0, 0.8, 60.0),
            Traffic::sessions(2.0, 4, 2.0),
            Traffic::Replay {
                path: "artifacts/t.json".into(),
            },
            Traffic::Custom {
                name: "surge".into(),
            },
        ] {
            cfg.workload.traffic = traffic;
            let back = SimConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(cfg, back, "traffic {:?}", cfg.workload.traffic);
        }
    }

    #[test]
    fn legacy_arrival_schema_still_parses() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.workload.traffic = Traffic::poisson(25.0);
        let mut v = cfg.to_json();
        // rewrite "traffic" to the pre-engine "arrival" key
        if let Value::Obj(top) = &mut v {
            if let Some(Value::Obj(w)) = top.get_mut("workload") {
                let t = w.remove("traffic").unwrap();
                w.insert("arrival".to_string(), t);
            }
        }
        let back = SimConfig::from_json(&v).unwrap();
        assert_eq!(back.workload.traffic, Traffic::poisson(25.0));
    }

    #[test]
    fn degenerate_workloads_rejected_at_validate() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.workload.traffic = Traffic::poisson(0.0);
        assert!(cfg.validate().is_err());
        cfg.workload.traffic = Traffic::poisson(10.0);
        cfg.workload.tenants = vec![TenantSpec::new("broke", 0.0, SloClass::Batch)];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn enum_string_roundtrips() {
        // Every enum now implements std::str::FromStr (not an inherent
        // shadow), so plain `.parse()` works and errors carry candidates.
        for r in [Role::Unified, Role::Prefill, Role::Decode] {
            assert_eq!(r.as_str().parse::<Role>().unwrap(), r);
        }
        assert!("bogus".parse::<Role>().unwrap_err().to_string().contains("unified"));
        for s in SchedPolicy::all() {
            assert_eq!(s.as_str().parse::<SchedPolicy>().unwrap(), *s);
            assert_eq!(s.to_policy().name(), s.as_str());
        }
        assert!("lifo"
            .parse::<SchedPolicy>()
            .unwrap_err()
            .to_string()
            .contains("fcfs"));
        for o in [
            OffloadPolicy::None,
            OffloadPolicy::OnDemand,
            OffloadPolicy::Prefetch,
            OffloadPolicy::Pim,
        ] {
            assert_eq!(o.as_str().parse::<OffloadPolicy>().unwrap(), o);
        }
        assert!("ssd"
            .parse::<OffloadPolicy>()
            .unwrap_err()
            .to_string()
            .contains("on-demand"));
        for k in [KvTransferPolicy::Blocking, KvTransferPolicy::Layered] {
            assert_eq!(k.as_str().parse::<KvTransferPolicy>().unwrap(), k);
        }
        assert!("streamed"
            .parse::<KvTransferPolicy>()
            .unwrap_err()
            .to_string()
            .contains("layered"));
    }

    #[test]
    fn cluster_block_roundtrips_and_defaults() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.controller = "queue-threshold".to_string();
        cfg.cluster.tick_ms = 50;
        cfg.cluster.max_instances = 4;
        cfg.cluster.failures = vec![
            FailureSpec {
                instance: 0,
                at_ms: 100,
                recover_ms: Some(400),
            },
            FailureSpec {
                instance: 1,
                at_ms: 250,
                recover_ms: None,
            },
        ];
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);
        // a config written before the cluster block existed parses to the
        // static defaults
        let mut v = cfg.to_json();
        if let Value::Obj(top) = &mut v {
            top.remove("cluster");
        }
        let back = SimConfig::from_json(&v).unwrap();
        assert_eq!(back.cluster, ClusterConfig::default());
        assert_eq!(back.cluster.controller, "static");
    }

    #[test]
    fn chaos_admission_and_zone_roundtrip() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.controller = "chaos".to_string();
        cfg.cluster.chaos = ChaosConfig::profile("heavy").unwrap();
        cfg.cluster.chaos.seed = 99;
        cfg.cluster.admission = Some(AdmissionConfig {
            rate: 50.0,
            burst: 8.0,
            breaker_queue: 64,
            breaker_cooldown_ms: 250,
        });
        cfg.instances[0].zone = "rack-a".to_string();
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(cfg, back);

        // keys are omitted when unconfigured — pre-chaos configs (and
        // byte-compat consumers) see an unchanged cluster block
        let cfg = presets::single_dense("tiny-dense", "rtx3090");
        let s = cfg.to_json().to_string();
        assert!(!s.contains("\"chaos\""), "{s}");
        assert!(!s.contains("\"admission\""), "{s}");
        assert!(!s.contains("\"zone\""), "{s}");
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.cluster.chaos, ChaosConfig::default());
        assert_eq!(back.cluster.admission, None);
        assert_eq!(back.instances[0].zone, "default");
    }

    #[test]
    fn chaos_profiles_resolve_and_unknown_errors_with_candidates() {
        for name in ChaosConfig::profile_names() {
            let p = ChaosConfig::profile(name).unwrap();
            p.validate().unwrap();
            assert_eq!(p.enabled(), *name != "none", "profile {name}");
        }
        let e = ChaosConfig::profile("mayhem").unwrap_err().to_string();
        assert!(e.contains("mayhem") && e.contains("light"), "{e}");
    }

    #[test]
    fn degenerate_chaos_and_admission_rejected() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.chaos.fault_rate = 1.0;
        cfg.cluster.chaos.mttr_ms = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.chaos.domain_correlation = 1.5;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.chaos.straggler_scale = 0.5;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.chaos.link_scale = 0.0;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.admission = Some(AdmissionConfig {
            rate: 0.0,
            ..AdmissionConfig::default()
        });
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.admission = Some(AdmissionConfig {
            burst: 0.5,
            ..AdmissionConfig::default()
        });
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn degenerate_cluster_configs_rejected() {
        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.tick_ms = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.min_instances = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.min_instances = 4;
        cfg.cluster.max_instances = 2;
        assert!(cfg.validate().is_err());

        let mut cfg = presets::single_dense("tiny-dense", "rtx3090");
        cfg.cluster.scale_up_queue = 1.0;
        cfg.cluster.scale_down_queue = 2.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn perf_backend_cli_roundtrips() {
        for b in [
            PerfBackend::Analytical,
            PerfBackend::Cycle,
            PerfBackend::CycleReplay,
            PerfBackend::Trace {
                path: "artifacts/traces/t.json".into(),
            },
        ] {
            assert_eq!(b.cli_str().parse::<PerfBackend>().unwrap(), b);
        }
        assert!("quantum".parse::<PerfBackend>().is_err());
    }
}
