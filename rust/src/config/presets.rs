//! Built-in configurations mirroring the paper's Table II:
//!
//! | Config  | Description                    | Instances / GPU per inst. |
//! |---------|--------------------------------|---------------------------|
//! | S(D/M)  | Single-instance Dense/MoE      | 1 inst., 1x RTX3090       |
//! | M(D/M)  | Multi-instance Dense/MoE       | 2 inst., 1x RTX3090       |
//! | PD(D/M) | P/D-disaggregated Dense/MoE    | 2 inst., 1x RTX3090       |
//! | * + PC  | any of the above + Prefix Cache|                           |
//!
//! Preset builders take the model/hardware names so the same shapes run
//! with tiny (executable) or paper-scale (analytical) models.

use super::{
    CacheScope, ClusterConfig, InstanceConfig, PerfBackend, PrefixCacheConfig, Role,
    SimConfig,
};
use crate::workload::{TenantSpec, Traffic, WorkloadSpec};

fn base(name: &str, instances: Vec<InstanceConfig>) -> SimConfig {
    SimConfig {
        name: name.to_string(),
        seed: 0xC0FFEE,
        instances,
        router: "least-outstanding".to_string(),
        workload: WorkloadSpec::sharegpt_100(10.0),
        perf: PerfBackend::Analytical,
        block_size: 16,
        inter_instance_bw: 32e9, // PCIe 4.0 x16 (paper §III-A)
        inter_instance_latency_ns: 5_000,
        cluster: ClusterConfig::default(),
    }
}

/// S(D) / S(M): single instance, one device.
pub fn single_dense(model: &str, hw: &str) -> SimConfig {
    base(
        "S(D)",
        vec![InstanceConfig::basic("inst0", model, hw)],
    )
}

pub fn single_moe(model: &str, hw: &str) -> SimConfig {
    let mut cfg = base("S(M)", vec![InstanceConfig::basic("inst0", model, hw)]);
    cfg.instances[0].gate = super::GateKind::Zipf { s: 1.0 };
    cfg
}

/// M(D) / M(M): two identical unified instances behind the router.
pub fn multi_dense(model: &str, hw: &str) -> SimConfig {
    base(
        "M(D)",
        vec![
            InstanceConfig::basic("inst0", model, hw),
            InstanceConfig::basic("inst1", model, hw),
        ],
    )
}

pub fn multi_moe(model: &str, hw: &str) -> SimConfig {
    let mut cfg = base(
        "M(M)",
        vec![
            InstanceConfig::basic("inst0", model, hw),
            InstanceConfig::basic("inst1", model, hw),
        ],
    );
    for i in &mut cfg.instances {
        i.gate = super::GateKind::Zipf { s: 1.0 };
    }
    cfg
}

/// PD(D) / PD(M): one prefill + one decode instance.
pub fn pd_dense(model: &str, hw: &str) -> SimConfig {
    let mut prefill = InstanceConfig::basic("prefill0", model, hw);
    prefill.role = Role::Prefill;
    let mut decode = InstanceConfig::basic("decode0", model, hw);
    decode.role = Role::Decode;
    base("PD(D)", vec![prefill, decode])
}

pub fn pd_moe(model: &str, hw: &str) -> SimConfig {
    let mut cfg = pd_dense(model, hw);
    cfg.name = "PD(M)".into();
    for i in &mut cfg.instances {
        i.gate = super::GateKind::Zipf { s: 1.0 };
    }
    cfg
}

/// Add prefix caching (the paper's `* + PC` variants). Enables sessions in
/// the workload so prefixes actually repeat.
pub fn with_prefix_cache(mut cfg: SimConfig, scope: CacheScope) -> SimConfig {
    cfg.name = format!("{}+PC", cfg.name);
    for i in &mut cfg.instances {
        i.prefix_cache = Some(PrefixCacheConfig {
            scope,
            ..PrefixCacheConfig::default()
        });
    }
    cfg.workload.sessions = 10;
    cfg.workload.shared_prefix = 64;
    if matches!(scope, CacheScope::Global) {
        cfg.router = "prefix-aware".to_string();
    }
    cfg
}

/// Turn any serving config into a multi-tenant bursty scenario: `tenants`
/// weighted tenants with alternating interactive/batch SLO classes, MMPP
/// on/off arrivals peaking at 4x `rate`, SLO-deadline scheduling on every
/// instance. The workload-engine counterpart of the `* + PC` transformer.
pub fn multi_tenant_bursty(mut cfg: SimConfig, tenants: usize, rate: f64) -> SimConfig {
    cfg.name = format!("{}+MT", cfg.name);
    cfg.workload.traffic = Traffic::for_name("mmpp", rate)
        // simlint: allow(S01) — literal name of a built-in source; P01 keeps
        // the builtin_names list and this call surface in sync
        .expect("mmpp is a built-in traffic source");
    cfg.workload.tenants = TenantSpec::mix(tenants.max(1));
    for i in &mut cfg.instances {
        i.sched = "slo".to_string();
    }
    cfg
}

/// The bursty autoscale scenario used by the controller tests,
/// `examples/autoscale.rs`, and the README walkthrough: a multi-tenant
/// MMPP workload whose bursts (50 ms at 2000 req/s) far exceed one
/// instance's service rate, with 300 ms quiet phases long enough to drain,
/// driven by the `queue-threshold` controller on a tight tick.
pub fn autoscale_bursty() -> SimConfig {
    let mut cfg =
        multi_tenant_bursty(single_dense("tiny-dense", "rtx3090"), 2, 60.0);
    cfg.name = "autoscale-bursty".to_string();
    cfg.workload.traffic = Traffic::mmpp(2000.0, 0.0, 0.05, 0.3);
    cfg.workload.num_requests = 200;
    cfg.workload.lengths = crate::workload::LengthDist::short();
    // A small batch cap so backlog shows up as *waiting* requests — the
    // signal the queue-threshold controller watches.
    for i in &mut cfg.instances {
        i.max_batch_seqs = 4;
    }
    cfg.cluster.controller = "queue-threshold".to_string();
    cfg.cluster.tick_ms = 10;
    cfg.cluster.warmup_ms = 30;
    cfg.cluster.scale_up_queue = 3.0;
    cfg.cluster.scale_down_queue = 1.0;
    // Low enough that the first burst saturates the ceiling — the
    // fleet-size timeline rises monotonically to max, then drains.
    cfg.cluster.max_instances = 3;
    cfg
}

/// The chaos-soak scenario used by `tests/integration_chaos.rs`,
/// `examples/chaos.rs`, and the README resilience walkthrough: the
/// multi-tenant bursty workload over three unified instances spread across
/// two zones, soaked under the `heavy` chaos profile — correlated zone
/// outages, fabric partitions, stragglers, and link degradations — for the
/// first five simulated seconds. Everything is seeded, so the full fault
/// timeline replays byte-identically.
pub fn chaos_soak() -> SimConfig {
    let mut cfg =
        multi_tenant_bursty(multi_dense("tiny-dense", "rtx3090"), 2, 60.0);
    cfg.name = "chaos-soak".to_string();
    cfg.instances
        .push(InstanceConfig::basic("inst2", "tiny-dense", "rtx3090"));
    for i in &mut cfg.instances {
        i.sched = "slo".to_string();
    }
    // Two failure domains: a zone outage takes out capacity but never the
    // whole fleet, so the run always finishes.
    cfg.instances[0].zone = "zone-a".to_string();
    cfg.instances[1].zone = "zone-a".to_string();
    cfg.instances[2].zone = "zone-b".to_string();
    cfg.workload.num_requests = 150;
    cfg.workload.lengths = crate::workload::LengthDist::short();
    cfg.cluster.controller = "chaos".to_string();
    cfg.cluster.tick_ms = 20;
    cfg.cluster.warmup_ms = 50;
    cfg.cluster.chaos = super::ChaosConfig {
        horizon_ms: 5_000,
        ..super::ChaosConfig::profile("heavy")
            // simlint: allow(S01) — literal name of a built-in profile; P01
            // keeps the profile_names list and this call surface in sync
            .expect("heavy is a built-in chaos profile")
    };
    cfg
}

/// Resolve a Table II serving-config name (`S(D)`, `M(M)`, `PD(D)+PC`, ...)
/// into a full [`SimConfig`], substituting the dense/MoE model and hardware
/// presets. Shared by the CLI (`simulate`) and the sweep engine's preset
/// axis; `None` for unknown names.
pub fn by_name(name: &str, dense: &str, moe: &str, hw: &str) -> Option<SimConfig> {
    Some(match name {
        "S(D)" => single_dense(dense, hw),
        "S(M)" => single_moe(moe, hw),
        "M(D)" => multi_dense(dense, hw),
        "M(M)" => multi_moe(moe, hw),
        "PD(D)" => pd_dense(dense, hw),
        "PD(M)" => pd_moe(moe, hw),
        "S(D)+PC" => with_prefix_cache(single_dense(dense, hw), CacheScope::PerInstance),
        "M(D)+PC" => with_prefix_cache(multi_dense(dense, hw), CacheScope::PerInstance),
        "PD(D)+PC" => with_prefix_cache(pd_dense(dense, hw), CacheScope::PerInstance),
        _ => return None,
    })
}

/// Names accepted by [`by_name`], in presentation order.
pub fn serving_preset_names() -> &'static [&'static str] {
    &[
        "S(D)", "S(M)", "M(D)", "M(M)", "PD(D)", "PD(M)", "S(D)+PC", "M(D)+PC",
        "PD(D)+PC",
    ]
}

/// The five Fig. 2 validation configs: SD, SM, MD, MM, PDD.
pub fn fig2_configs(dense: &str, moe: &str, hw: &str) -> Vec<SimConfig> {
    vec![
        single_dense(dense, hw),
        single_moe(moe, hw),
        multi_dense(dense, hw),
        multi_moe(moe, hw),
        pd_dense(dense, hw),
    ]
}

/// The nine Fig. 3 simulation-time configs: S/M/PD x D/M plus PC variants
/// (SD+PC, MD+PC, PDD+PC).
pub fn fig3_configs(dense: &str, moe: &str, hw: &str) -> Vec<SimConfig> {
    vec![
        single_dense(dense, hw),
        single_moe(moe, hw),
        multi_dense(dense, hw),
        multi_moe(moe, hw),
        pd_dense(dense, hw),
        pd_moe(moe, hw),
        with_prefix_cache(single_dense(dense, hw), CacheScope::PerInstance),
        with_prefix_cache(multi_dense(dense, hw), CacheScope::PerInstance),
        with_prefix_cache(pd_dense(dense, hw), CacheScope::PerInstance),
    ]
}

/// All Table II shapes (fig2 + PD(M)) for config tests.
pub fn all_table2(dense: &str, moe: &str, hw: &str) -> Vec<SimConfig> {
    let mut v = fig2_configs(dense, moe, hw);
    v.push(pd_moe(moe, hw));
    v.push(with_prefix_cache(single_dense(dense, hw), CacheScope::Global));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_has_five_validating_configs() {
        let cfgs = fig2_configs("tiny-dense", "tiny-moe", "rtx3090");
        assert_eq!(cfgs.len(), 5);
        for c in &cfgs {
            c.validate().unwrap();
        }
        let names: Vec<&str> = cfgs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["S(D)", "S(M)", "M(D)", "M(M)", "PD(D)"]);
    }

    #[test]
    fn fig3_has_nine_validating_configs() {
        let cfgs = fig3_configs("tiny-dense", "tiny-moe", "rtx3090");
        assert_eq!(cfgs.len(), 9);
        for c in &cfgs {
            c.validate().unwrap();
        }
    }

    #[test]
    fn pd_configs_have_both_roles() {
        let cfg = pd_dense("tiny-dense", "rtx3090");
        assert!(cfg.instances.iter().any(|i| i.role == Role::Prefill));
        assert!(cfg.instances.iter().any(|i| i.role == Role::Decode));
    }

    #[test]
    fn pc_variant_enables_sessions() {
        let cfg = with_prefix_cache(
            single_dense("tiny-dense", "rtx3090"),
            CacheScope::PerInstance,
        );
        assert_eq!(cfg.name, "S(D)+PC");
        assert!(cfg.workload.sessions > 0);
        assert!(cfg.instances[0].prefix_cache.is_some());
    }

    #[test]
    fn by_name_covers_every_listed_preset() {
        for name in serving_preset_names() {
            let cfg = by_name(name, "tiny-dense", "tiny-moe", "rtx3090")
                .unwrap_or_else(|| panic!("preset '{name}' not resolvable"));
            cfg.validate().unwrap();
            assert_eq!(&cfg.name, name);
        }
        assert!(by_name("X(Q)", "tiny-dense", "tiny-moe", "rtx3090").is_none());
    }

    #[test]
    fn multi_tenant_transformer_sets_traffic_and_sched() {
        let cfg = multi_tenant_bursty(multi_dense("tiny-dense", "rtx3090"), 3, 10.0);
        assert_eq!(cfg.name, "M(D)+MT");
        assert_eq!(cfg.workload.traffic.kind_name(), "mmpp");
        assert_eq!(cfg.workload.tenants.len(), 3);
        assert!(cfg.instances.iter().all(|i| i.sched == "slo"));
        cfg.validate().unwrap();
    }

    #[test]
    fn chaos_soak_preset_validates_with_two_zones() {
        let cfg = chaos_soak();
        cfg.validate().unwrap();
        assert_eq!(cfg.cluster.controller, "chaos");
        assert!(cfg.cluster.chaos.enabled());
        assert_eq!(cfg.cluster.chaos.horizon_ms, 5_000);
        let zones: std::collections::BTreeSet<&str> =
            cfg.instances.iter().map(|i| i.zone.as_str()).collect();
        assert_eq!(zones.len(), 2, "soak needs two failure domains");
        assert!(cfg.instances.iter().all(|i| i.sched == "slo"));
    }

    #[test]
    fn global_pc_uses_prefix_aware_routing() {
        let cfg = with_prefix_cache(
            multi_dense("tiny-dense", "rtx3090"),
            CacheScope::Global,
        );
        assert_eq!(cfg.router, "prefix-aware");
    }
}
