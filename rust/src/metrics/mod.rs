//! Serving metrics: TTFT, TPOT, ITL, end-to-end latency, token throughput,
//! per-instance utilization, SLO attainment/goodput, and per-tenant /
//! per-class breakdowns — the quantities Fig. 2 reports (average TPOT, ITL,
//! and token generation throughput) plus the multi-tenant extensions.
//!
//! Definitions (matching vLLM's benchmark conventions, which the paper
//! compares against):
//! * **TTFT** — arrival to first output token.
//! * **TPOT** — (end-to-end latency - TTFT) / (output tokens - 1).
//! * **ITL**  — individual gaps between consecutive output tokens.
//! * **Throughput** — total generated tokens / makespan.
//! * **SLO attainment** — fraction of finished requests meeting both the
//!   TTFT and TPOT targets of their [`SloClass`].
//! * **Goodput** — throughput counting only tokens of SLO-met requests
//!   (the useful work actually delivered within objectives).
//!
//! Memory contract: the collector is **streaming**. Per-request state lives
//! only while a request is in flight; at finish it is folded into scalar
//! aggregates and bounded [`SampleSet`] reservoirs (exact below
//! [`SAMPLE_RESERVOIR_CAP`](crate::util::stats::SAMPLE_RESERVOIR_CAP)
//! samples, deterministic sampling beyond). Million-request workloads
//! therefore run in memory bounded by in-flight requests, not by history.

use std::collections::BTreeMap;

use crate::util::fxhash::FxHashMap;

use crate::cluster::TimelineEntry;
use crate::sim::{nanos_to_secs, Nanos};
use crate::util::json::Value;
use crate::util::stats::{self, SampleSet, Summary};
use crate::workload::{Request, SloClass};

/// Lifecycle timestamps for one in-flight request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: Nanos,
    pub dispatched: Option<Nanos>,
    pub instance: Option<usize>,
    pub token_times: Vec<Nanos>,
    pub finished: Option<Nanos>,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
    /// Prompt tokens served from the prefix cache (any tier).
    pub cached_tokens: u64,
    pub tenant: u32,
    pub slo_class: SloClass,
}

impl RequestRecord {
    pub fn ttft(&self) -> Option<Nanos> {
        self.token_times.first().map(|&t| t - self.arrival)
    }

    pub fn e2e(&self) -> Option<Nanos> {
        self.finished.map(|f| f - self.arrival)
    }

    /// Time per output token (excluding the first).
    pub fn tpot(&self) -> Option<f64> {
        let e2e = self.e2e()? as f64;
        let ttft = self.ttft()? as f64;
        let n = self.token_times.len();
        if n <= 1 {
            return None;
        }
        Some((e2e - ttft) / (n - 1) as f64)
    }

    /// Inter-token latencies.
    pub fn itls(&self) -> Vec<f64> {
        self.token_times
            .windows(2)
            .map(|w| (w[1] - w[0]) as f64)
            // simlint: allow(H01) — consumed once per request at finish time
            // to fold gaps into the ITL aggregate, not per token or per event
            .collect()
    }

    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }

    /// `(ttft_ok, tpot_ok)` against the class targets — the single source
    /// of truth for SLO semantics (a request with no first token misses
    /// TTFT; a single-token output meets TPOT vacuously).
    fn slo_flags(&self) -> (bool, bool) {
        let ttft_ok = self
            .ttft()
            .is_some_and(|t| t <= self.slo_class.ttft_target_ns());
        let tpot_ok = self
            .tpot()
            .is_none_or(|t| t <= self.slo_class.tpot_target_ns() as f64);
        (ttft_ok, tpot_ok)
    }

    /// Whether this (finished) request met its class's TTFT/TPOT targets.
    pub fn meets_slo(&self) -> bool {
        let (ttft_ok, tpot_ok) = self.slo_flags();
        ttft_ok && tpot_ok
    }
}

/// Streaming per-class aggregates.
#[derive(Debug, Clone, Default)]
struct ClassAgg {
    finished: u64,
    gen_tokens: u64,
    ttft_ok: u64,
    tpot_ok: u64,
    slo_ok: u64,
    good_tokens: u64,
}

/// Streaming per-tenant aggregates.
#[derive(Debug, Clone, Default)]
struct TenantAgg {
    finished: u64,
    gen_tokens: u64,
    slo_ok: u64,
    ttft_sum: f64,
    ttft_n: u64,
}

/// Collects per-request lifecycle events during a simulation.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    /// In-flight records only; folded into aggregates at finish.
    records: FxHashMap<u64, RequestRecord>,
    /// Per-instance busy time accumulation.
    busy: FxHashMap<usize, Nanos>,
    arrivals: usize,
    finished: usize,
    /// Requests refused by admission control (terminal: never dispatched).
    rejected: usize,
    gen_tokens: u64,
    cached_tokens: u64,
    good_tokens: u64,
    ttft: SampleSet,
    tpot: SampleSet,
    itl: SampleSet,
    e2e: SampleSet,
    classes: BTreeMap<SloClass, ClassAgg>,
    tenants: BTreeMap<u32, TenantAgg>,
    // ---- fault-window accounting (chaos — DESIGN.md §12) ----
    /// Concurrently-open fault windows (instances failed, not yet
    /// re-`Active`). The union of depth>0 time is `fault_ns`.
    fault_depth: u32,
    /// Start of the current depth>0 window (meaningful when depth > 0).
    fault_started: Nanos,
    /// Fault windows opened (one per instance failure).
    faults: u64,
    /// Closed depth>0 time so far (open window added at report time).
    fault_ns: Nanos,
    /// Finishes that landed while at least one fault window was open.
    fin_in_fault: u64,
    /// SLO-met finishes among `fin_in_fault`.
    slo_ok_in_fault: u64,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_arrival(&mut self, req: &Request, at: Nanos) {
        self.arrivals += 1;
        self.records.insert(
            req.id,
            RequestRecord {
                id: req.id,
                arrival: at,
                dispatched: None,
                instance: None,
                // simlint: allow(H01) — capacity-0 `vec![]`, allocates only as
                // tokens arrive; one record per request admission
                token_times: vec![],
                finished: None,
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.output_tokens,
                cached_tokens: 0,
                tenant: req.tenant,
                slo_class: req.slo_class,
            },
        );
    }

    pub fn on_dispatch(&mut self, id: u64, at: Nanos, instance: usize) {
        if let Some(r) = self.records.get_mut(&id) {
            r.dispatched = Some(at);
            r.instance = Some(instance);
        }
    }

    pub fn on_cached(&mut self, id: u64, tokens: u64) {
        if let Some(r) = self.records.get_mut(&id) {
            r.cached_tokens = tokens;
        }
    }

    pub fn on_token(&mut self, id: u64, at: Nanos) {
        if let Some(r) = self.records.get_mut(&id) {
            r.token_times.push(at);
        }
    }

    /// Finish a request: fold its record into the streaming aggregates and
    /// drop the per-request state.
    pub fn on_finish(&mut self, id: u64, at: Nanos) {
        let Some(mut r) = self.records.remove(&id) else {
            return;
        };
        r.finished = Some(at);
        self.finished += 1;
        let tokens = r.token_times.len() as u64;
        self.gen_tokens += tokens;
        self.cached_tokens += r.cached_tokens;

        let ttft = r.ttft();
        let tpot = r.tpot();
        if let Some(t) = ttft {
            self.ttft.push(t as f64);
        }
        if let Some(t) = tpot {
            self.tpot.push(t);
        }
        if let Some(t) = r.e2e() {
            self.e2e.push(t as f64);
        }
        for gap in r.itls() {
            self.itl.push(gap);
        }

        let (ttft_ok, tpot_ok) = r.slo_flags();
        let slo_ok = ttft_ok && tpot_ok;
        if slo_ok {
            self.good_tokens += tokens;
        }
        if self.fault_depth > 0 {
            self.fin_in_fault += 1;
            self.slo_ok_in_fault += slo_ok as u64;
        }

        let c = self.classes.entry(r.slo_class).or_default();
        c.finished += 1;
        c.gen_tokens += tokens;
        c.ttft_ok += ttft_ok as u64;
        c.tpot_ok += tpot_ok as u64;
        c.slo_ok += slo_ok as u64;
        if slo_ok {
            c.good_tokens += tokens;
        }

        let t = self.tenants.entry(r.tenant).or_default();
        t.finished += 1;
        t.gen_tokens += tokens;
        t.slo_ok += slo_ok as u64;
        if let Some(x) = ttft {
            t.ttft_sum += x as f64;
            t.ttft_n += 1;
        }
    }

    pub fn on_busy(&mut self, instance: usize, dur: Nanos) {
        *self.busy.entry(instance).or_insert(0) += dur;
    }

    /// Admission control refused this arrival: the request is terminal.
    /// Its record (created by [`on_arrival`](Self::on_arrival)) is dropped
    /// so it never counts as in-flight; conservation becomes
    /// `arrivals == finished + in_flight + rejected`.
    pub fn on_rejected(&mut self, id: u64) {
        if self.records.remove(&id).is_some() {
            self.rejected += 1;
        }
    }

    pub fn num_rejected(&self) -> usize {
        self.rejected
    }

    /// An instance failed: open one fault window. Windows may overlap
    /// (correlated domain outages); `fault_ns` tracks the *union*.
    pub fn on_fault_begin(&mut self, now: Nanos) {
        self.faults += 1;
        if self.fault_depth == 0 {
            self.fault_started = now;
        }
        self.fault_depth += 1;
    }

    /// A failed instance returned to `Active`: close its fault window.
    pub fn on_fault_end(&mut self, now: Nanos) {
        if self.fault_depth == 0 {
            return; // unbalanced end: ignore rather than corrupt the union
        }
        self.fault_depth -= 1;
        if self.fault_depth == 0 {
            self.fault_ns = self
                .fault_ns
                .saturating_add(now.saturating_sub(self.fault_started));
        }
    }

    /// Whether at least one fault window is currently open.
    pub fn in_fault(&self) -> bool {
        self.fault_depth > 0
    }

    /// In-flight record lookup (finished records are folded and dropped).
    pub fn record(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(&id)
    }

    pub fn num_finished(&self) -> usize {
        self.finished
    }

    pub fn num_in_flight(&self) -> usize {
        self.records.len()
    }

    pub fn num_arrivals(&self) -> usize {
        self.arrivals
    }

    /// SLO attainment over requests finished *so far* (1.0 when none) —
    /// the mid-run signal cluster controllers see in their
    /// [`ClusterView`](crate::cluster::ClusterView).
    pub fn slo_attainment_so_far(&self) -> f64 {
        let finished: u64 = self.classes.values().map(|c| c.finished).sum();
        if finished == 0 {
            1.0
        } else {
            self.classes.values().map(|c| c.slo_ok).sum::<u64>() as f64
                / finished as f64
        }
    }

    /// Build the final report. `makespan` is the simulation end time;
    /// `tenant_names` labels tenant indices (out-of-range indices name
    /// themselves).
    pub fn report(&self, makespan: Nanos, tenant_names: &[String]) -> Report {
        let secs = nanos_to_secs(makespan).max(1e-12);
        let utilization: BTreeMap<usize, f64> = self
            .busy
            // simlint: allow(D04) — collected into a BTreeMap, so the
            // result is sorted regardless of hash-iteration order
            .iter()
            .map(|(&i, &b)| (i, (b as f64 / makespan.max(1) as f64).min(1.0)))
            .collect();
        let per_class = self
            .classes
            .iter()
            .map(|(&class, c)| {
                let f = c.finished.max(1) as f64;
                ClassReport {
                    class,
                    num_finished: c.finished as usize,
                    generated_tokens: c.gen_tokens,
                    ttft_attainment: c.ttft_ok as f64 / f,
                    tpot_attainment: c.tpot_ok as f64 / f,
                    slo_attainment: c.slo_ok as f64 / f,
                    goodput_tps: c.good_tokens as f64 / secs,
                }
            })
            .collect();
        let per_tenant = self
            .tenants
            .iter()
            .map(|(&tenant, t)| TenantReport {
                tenant,
                name: tenant_names
                    .get(tenant as usize)
                    .cloned()
                    .unwrap_or_else(|| format!("tenant{tenant}")),
                num_finished: t.finished as usize,
                generated_tokens: t.gen_tokens,
                throughput_tps: t.gen_tokens as f64 / secs,
                slo_attainment: t.slo_ok as f64 / t.finished.max(1) as f64,
                ttft_ns_mean: if t.ttft_n > 0 {
                    t.ttft_sum / t.ttft_n as f64
                } else {
                    0.0
                },
            })
            .collect();
        // Fault-window rollup: close the open window at makespan; split
        // SLO attainment into fault-time vs clear-time finishes (both
        // vacuously 1.0 when the respective bucket is empty).
        let mut fault_ns = self.fault_ns;
        if self.fault_depth > 0 {
            fault_ns =
                fault_ns.saturating_add(makespan.saturating_sub(self.fault_started));
        }
        let slo_ok_total: u64 = self.classes.values().map(|c| c.slo_ok).sum();
        let resilience = (self.faults > 0).then(|| {
            let fin_clear = (self.finished as u64).saturating_sub(self.fin_in_fault);
            let slo_ok_clear = slo_ok_total.saturating_sub(self.slo_ok_in_fault);
            ResilienceReport {
                faults: self.faults,
                fault_ns,
                finished_in_fault: self.fin_in_fault as usize,
                slo_in_fault: if self.fin_in_fault == 0 {
                    1.0
                } else {
                    self.slo_ok_in_fault as f64 / self.fin_in_fault as f64
                },
                slo_clear: if fin_clear == 0 {
                    1.0
                } else {
                    slo_ok_clear as f64 / fin_clear as f64
                },
                // Filled by the coordinator, which owns zone labels.
                domains: vec![],
            }
        });
        Report {
            num_requests: self.arrivals,
            num_finished: self.finished,
            rejected: self.rejected,
            resilience,
            makespan,
            ttft_ns: self.ttft.summary(),
            tpot_ns: self.tpot.summary(),
            itl_ns: self.itl.summary(),
            e2e_ns: self.e2e.summary(),
            generated_tokens: self.gen_tokens,
            cached_tokens: self.cached_tokens,
            throughput_tps: self.gen_tokens as f64 / secs,
            goodput_tps: self.good_tokens as f64 / secs,
            utilization,
            per_class,
            per_tenant,
            controller: "static".to_string(),
            timeline: vec![],
        }
    }
}

/// Per-SLO-class slice of a report.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: SloClass,
    pub num_finished: usize,
    pub generated_tokens: u64,
    /// Fraction of finished requests meeting the TTFT target.
    pub ttft_attainment: f64,
    /// Fraction meeting the TPOT target.
    pub tpot_attainment: f64,
    /// Fraction meeting both targets.
    pub slo_attainment: f64,
    /// Tokens/s from SLO-met requests of this class.
    pub goodput_tps: f64,
}

/// Per-tenant slice of a report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: u32,
    pub name: String,
    pub num_finished: usize,
    pub generated_tokens: u64,
    pub throughput_tps: f64,
    pub slo_attainment: f64,
    pub ttft_ns_mean: f64,
}

/// Resilience rollup for runs that saw instance faults (chaos scenarios —
/// DESIGN.md §12). Omitted from the JSON when no fault window ever opened,
/// keeping fault-free reports byte-identical to pre-chaos output.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    /// Fault windows opened (one per instance failure).
    pub faults: u64,
    /// Union of time at least one fault window was open (ns).
    pub fault_ns: Nanos,
    /// Requests that finished while a fault window was open.
    pub finished_in_fault: usize,
    /// SLO attainment over fault-window finishes (1.0 when none).
    pub slo_in_fault: f64,
    /// SLO attainment over clear-time finishes (1.0 when none).
    pub slo_clear: f64,
    /// Per-failure-domain availability, in zone-name order.
    pub domains: Vec<DomainReport>,
}

/// Availability of one failure domain (zone) over the run.
#[derive(Debug, Clone)]
pub struct DomainReport {
    pub zone: String,
    /// Instances labelled with this zone.
    pub instances: usize,
    /// Summed per-instance fault time (fail → re-`Active`), ns.
    pub downtime_ns: Nanos,
    /// `1 - downtime / (instances * makespan)`.
    pub availability: f64,
}

/// Final simulation report (one Fig. 2 data point).
#[derive(Debug, Clone)]
pub struct Report {
    pub num_requests: usize,
    pub num_finished: usize,
    /// Requests refused by admission control (0 when admission is off —
    /// the key is then omitted from the JSON).
    pub rejected: usize,
    /// Fault-window rollup; `None` when the run saw no instance faults.
    pub resilience: Option<ResilienceReport>,
    pub makespan: Nanos,
    pub ttft_ns: Summary,
    pub tpot_ns: Summary,
    pub itl_ns: Summary,
    pub e2e_ns: Summary,
    pub generated_tokens: u64,
    pub cached_tokens: u64,
    /// Output tokens per second.
    pub throughput_tps: f64,
    /// Output tokens per second from requests that met their SLO.
    pub goodput_tps: f64,
    /// Per-instance busy fraction, sorted by instance id (determinism:
    /// enumeration order is part of the report byte contract).
    pub utilization: BTreeMap<usize, f64>,
    /// Per-SLO-class breakdown, ordered by class.
    pub per_class: Vec<ClassReport>,
    /// Per-tenant breakdown, ordered by tenant index.
    pub per_tenant: Vec<TenantReport>,
    /// Name of the cluster controller that ran (`"static"` = frozen
    /// fleet; the coordinator overwrites this after the run).
    pub controller: String,
    /// Controller actions, lifecycle transitions, and fleet-size samples
    /// in event order. Empty under the `static` controller — and omitted
    /// from the JSON then, keeping static reports byte-identical to
    /// pre-driver output.
    pub timeline: Vec<TimelineEntry>,
}

impl Report {
    pub fn to_json(&self) -> Value {
        let sum = |s: &Summary| {
            Value::obj(vec![
                ("mean", Value::float(s.mean)),
                ("p50", Value::float(s.p50)),
                ("p90", Value::float(s.p90)),
                ("p99", Value::float(s.p99)),
                ("count", Value::int(s.count as i64)),
            ])
        };
        let mut fields = vec![
            ("num_requests", Value::int(self.num_requests as i64)),
            ("num_finished", Value::int(self.num_finished as i64)),
            ("makespan_ns", Value::int(self.makespan as i64)),
            ("ttft_ns", sum(&self.ttft_ns)),
            ("tpot_ns", sum(&self.tpot_ns)),
            ("itl_ns", sum(&self.itl_ns)),
            ("e2e_ns", sum(&self.e2e_ns)),
            ("generated_tokens", Value::int(self.generated_tokens as i64)),
            ("cached_tokens", Value::int(self.cached_tokens as i64)),
            ("throughput_tps", Value::float(self.throughput_tps)),
            ("goodput_tps", Value::float(self.goodput_tps)),
            (
                "utilization",
                Value::arr(
                    self.utilization
                        .iter()
                        .map(|(&k, &v)| {
                            Value::obj(vec![
                                ("instance", Value::int(k as i64)),
                                ("busy", Value::float(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "slo_classes",
                Value::arr(
                    self.per_class
                        .iter()
                        .map(|c| {
                            Value::obj(vec![
                                ("class", Value::str(c.class.as_str())),
                                ("num_finished", Value::int(c.num_finished as i64)),
                                (
                                    "generated_tokens",
                                    Value::int(c.generated_tokens as i64),
                                ),
                                ("ttft_attainment", Value::float(c.ttft_attainment)),
                                ("tpot_attainment", Value::float(c.tpot_attainment)),
                                ("slo_attainment", Value::float(c.slo_attainment)),
                                ("goodput_tps", Value::float(c.goodput_tps)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "tenants",
                Value::arr(
                    self.per_tenant
                        .iter()
                        .map(|t| {
                            Value::obj(vec![
                                ("tenant", Value::int(t.tenant as i64)),
                                ("name", Value::str(t.name.clone())),
                                ("num_finished", Value::int(t.num_finished as i64)),
                                (
                                    "generated_tokens",
                                    Value::int(t.generated_tokens as i64),
                                ),
                                ("throughput_tps", Value::float(t.throughput_tps)),
                                ("slo_attainment", Value::float(t.slo_attainment)),
                                ("ttft_ns_mean", Value::float(t.ttft_ns_mean)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        // Chaos/admission keys only when those subsystems actually acted:
        // fault-free, admission-free runs stay byte-identical.
        if self.rejected > 0 {
            fields.push(("rejected", Value::int(self.rejected as i64)));
        }
        if let Some(res) = &self.resilience {
            fields.push((
                "resilience",
                Value::obj(vec![
                    ("faults", Value::int(res.faults as i64)),
                    ("fault_ns", Value::int(res.fault_ns as i64)),
                    (
                        "finished_in_fault",
                        Value::int(res.finished_in_fault as i64),
                    ),
                    ("slo_in_fault", Value::float(res.slo_in_fault)),
                    ("slo_clear", Value::float(res.slo_clear)),
                    (
                        "domains",
                        Value::arr(
                            res.domains
                                .iter()
                                .map(|d| {
                                    Value::obj(vec![
                                        ("zone", Value::str(d.zone.clone())),
                                        (
                                            "instances",
                                            Value::int(d.instances as i64),
                                        ),
                                        (
                                            "downtime_ns",
                                            Value::int(d.downtime_ns as i64),
                                        ),
                                        (
                                            "availability",
                                            Value::float(d.availability),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ));
        }
        // Cluster-dynamics keys only when a controller actually ran:
        // static reports stay byte-identical to pre-driver output.
        if self.controller != "static" || !self.timeline.is_empty() {
            fields.push(("controller", Value::str(self.controller.clone())));
            fields.push((
                "timeline",
                Value::arr(self.timeline.iter().map(|e| e.to_json()).collect()),
            ));
        }
        Value::obj(fields)
    }

    /// Mean absolute percentage error of headline metrics vs a reference
    /// report (used by Fig. 2 validation: TPOT, ITL, throughput).
    pub fn error_vs(&self, reference: &Report) -> ValidationError {
        ValidationError {
            tpot_pct: stats::ape(self.tpot_ns.mean, reference.tpot_ns.mean),
            itl_pct: stats::ape(self.itl_ns.mean, reference.itl_ns.mean),
            throughput_pct: stats::ape(self.throughput_tps, reference.throughput_tps),
            ttft_pct: stats::ape(self.ttft_ns.mean, reference.ttft_ns.mean),
        }
    }
}

/// Extract a headline sweep metric from a report's **JSON** form, using
/// the same definitions as the struct extractors in
/// [`crate::sweep::METRICS`]. This is the one place the JSON shape of a
/// report is interpreted numerically: the shard-merge path recomputes
/// sweep summaries from round-tripped report files, and because finite
/// floats serialize via shortest round-trip repr, the value recovered
/// here is bit-equal to the one the in-memory extractor saw.
///
/// Returns `None` for an unknown key or a report missing the field.
pub fn headline_from_json(report: &Value, key: &str) -> Option<f64> {
    match key {
        "ttft_mean_ms" => report.get("ttft_ns").get("mean").as_f64().map(|v| v / 1e6),
        "tpot_mean_ms" => report.get("tpot_ns").get("mean").as_f64().map(|v| v / 1e6),
        "itl_mean_ms" => report.get("itl_ns").get("mean").as_f64().map(|v| v / 1e6),
        "throughput_tps" => report.get("throughput_tps").as_f64(),
        "makespan_s" => report.get("makespan_ns").as_i64().map(|v| v as f64 / 1e9),
        _ => None,
    }
}

/// Percentage errors of a simulated report against a reference run.
#[derive(Debug, Clone, Copy)]
pub struct ValidationError {
    pub tpot_pct: f64,
    pub itl_pct: f64,
    pub throughput_pct: f64,
    pub ttft_pct: f64,
}

impl ValidationError {
    pub fn mean(&self) -> f64 {
        (self.tpot_pct + self.itl_pct + self.throughput_pct) / 3.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrive(m: &mut MetricsCollector, id: u64, at: Nanos, prompt: u64, output: u64) {
        m.on_arrival(
            &Request {
                id,
                arrival: at,
                prompt_tokens: prompt,
                output_tokens: output,
                ..Request::default()
            },
            at,
        );
    }

    fn collect_one() -> MetricsCollector {
        let mut m = MetricsCollector::new();
        arrive(&mut m, 0, 1000, 32, 4);
        m.on_dispatch(0, 1500, 0);
        m.on_token(0, 2000);
        m.on_token(0, 2500);
        m.on_token(0, 3100);
        m.on_token(0, 3600);
        m.on_finish(0, 3600);
        m
    }

    fn hand_record() -> RequestRecord {
        RequestRecord {
            id: 0,
            arrival: 1000,
            dispatched: Some(1500),
            instance: Some(0),
            token_times: vec![2000, 2500, 3100, 3600],
            finished: Some(3600),
            prompt_tokens: 32,
            output_tokens: 4,
            cached_tokens: 0,
            tenant: 0,
            slo_class: SloClass::Interactive,
        }
    }

    #[test]
    fn ttft_tpot_itl() {
        let r = hand_record();
        assert_eq!(r.ttft(), Some(1000));
        assert_eq!(r.e2e(), Some(2600));
        // tpot = (2600-1000)/3
        assert!((r.tpot().unwrap() - 1600.0 / 3.0).abs() < 1e-9);
        assert_eq!(r.itls(), vec![500.0, 600.0, 500.0]);
        assert!(r.meets_slo(), "ns-scale latencies beat interactive targets");
    }

    #[test]
    fn single_token_has_no_tpot() {
        let mut r = hand_record();
        r.token_times = vec![100];
        assert!(r.tpot().is_none());
        assert!(r.meets_slo(), "TPOT vacuously met for single-token output");
    }

    #[test]
    fn slo_miss_detected() {
        let mut r = hand_record();
        // push TTFT past the interactive 500 ms target
        r.token_times = vec![1000 + SloClass::Interactive.ttft_target_ns() + 1];
        assert!(!r.meets_slo());
        // the same latency is fine for batch
        r.slo_class = SloClass::Batch;
        assert!(r.meets_slo());
    }

    #[test]
    fn report_aggregates() {
        let m = collect_one();
        let rep = m.report(10_000, &[]);
        assert_eq!(rep.num_finished, 1);
        assert_eq!(rep.generated_tokens, 4);
        assert!((rep.throughput_tps - 4.0 / 1e-5).abs() < 1.0);
        assert_eq!(rep.ttft_ns.mean, 1000.0);
        // summary percentiles match the exact path below the reservoir cap
        assert_eq!(rep.itl_ns.count, 3);
        assert_eq!(rep.itl_ns.p50, 500.0);
        // all requests met SLO → goodput == throughput
        assert!((rep.goodput_tps - rep.throughput_tps).abs() < 1e-9);
    }

    #[test]
    fn unfinished_requests_excluded() {
        let mut m = collect_one();
        arrive(&mut m, 1, 2000, 16, 8);
        m.on_token(1, 3000);
        let rep = m.report(10_000, &[]);
        assert_eq!(rep.num_requests, 2);
        assert_eq!(rep.num_finished, 1);
        assert_eq!(m.num_in_flight(), 1, "unfinished stays in flight");
        assert!(m.record(1).is_some());
        assert!(m.record(0).is_none(), "finished records are folded away");
    }

    #[test]
    fn utilization_capped() {
        let mut m = collect_one();
        m.on_busy(0, 5_000);
        m.on_busy(0, 4_000);
        let rep = m.report(10_000, &[]);
        assert!((rep.utilization[&0] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn per_class_attainment_and_goodput() {
        let mut m = MetricsCollector::new();
        // interactive hit: instant tokens
        m.on_arrival(
            &Request {
                id: 0,
                prompt_tokens: 8,
                output_tokens: 2,
                ..Request::default()
            },
            0,
        );
        m.on_token(0, 100);
        m.on_token(0, 200);
        m.on_finish(0, 200);
        // interactive miss: first token far past the 500 ms target
        m.on_arrival(
            &Request {
                id: 1,
                prompt_tokens: 8,
                output_tokens: 2,
                ..Request::default()
            },
            0,
        );
        let late = SloClass::Interactive.ttft_target_ns() * 2;
        m.on_token(1, late);
        m.on_token(1, late + 100);
        m.on_finish(1, late + 100);
        // batch hit with the same lateness
        m.on_arrival(
            &Request {
                id: 2,
                prompt_tokens: 8,
                output_tokens: 2,
                slo_class: SloClass::Batch,
                ..Request::default()
            },
            0,
        );
        m.on_token(2, late);
        m.on_token(2, late + 100);
        m.on_finish(2, late + 100);

        let rep = m.report(late + 100, &[]);
        assert_eq!(rep.per_class.len(), 2);
        let inter = &rep.per_class[0];
        assert_eq!(inter.class, SloClass::Interactive);
        assert_eq!(inter.num_finished, 2);
        assert!((inter.ttft_attainment - 0.5).abs() < 1e-9);
        assert!((inter.slo_attainment - 0.5).abs() < 1e-9);
        let batch = &rep.per_class[1];
        assert_eq!(batch.class, SloClass::Batch);
        assert!((batch.slo_attainment - 1.0).abs() < 1e-9);
        // goodput counts 4 of the 6 tokens (ids 0 and 2)
        let secs = nanos_to_secs(late + 100);
        assert!((rep.goodput_tps - 4.0 / secs).abs() < 1e-6);
        assert!((rep.throughput_tps - 6.0 / secs).abs() < 1e-6);
    }

    #[test]
    fn per_tenant_aggregation_with_names() {
        let mut m = MetricsCollector::new();
        for (id, tenant) in [(0u64, 0u32), (1, 1), (2, 1)] {
            m.on_arrival(
                &Request {
                    id,
                    prompt_tokens: 8,
                    output_tokens: 1,
                    tenant,
                    ..Request::default()
                },
                0,
            );
            m.on_token(id, 50 + id);
            m.on_finish(id, 50 + id);
        }
        let rep = m.report(1_000, &["alpha".into(), "beta".into()]);
        assert_eq!(rep.per_tenant.len(), 2);
        assert_eq!(rep.per_tenant[0].name, "alpha");
        assert_eq!(rep.per_tenant[0].num_finished, 1);
        assert_eq!(rep.per_tenant[1].name, "beta");
        assert_eq!(rep.per_tenant[1].num_finished, 2);
        assert_eq!(rep.per_tenant[1].generated_tokens, 2);
        assert!((rep.per_tenant[1].ttft_ns_mean - 51.5).abs() < 1e-9);
        assert!((rep.per_tenant[0].slo_attainment - 1.0).abs() < 1e-9);
        // unnamed tenants label themselves
        let rep = m.report(1_000, &[]);
        assert_eq!(rep.per_tenant[1].name, "tenant1");
    }

    #[test]
    fn cluster_keys_omitted_for_static_and_emitted_otherwise() {
        let rep = collect_one().report(10_000, &[]);
        // static + empty timeline -> no cluster keys, byte-stable output
        assert_eq!(rep.controller, "static");
        let v = rep.to_json();
        assert!(v.get("controller").is_null());
        assert!(v.get("timeline").is_null());
        // a controller run emits both keys
        let mut rep = rep;
        rep.controller = "queue-threshold".to_string();
        rep.timeline.push(TimelineEntry {
            at: 7,
            kind: "scale-up".into(),
            instance: Some(1),
            active: 2,
            detail: String::new(),
        });
        let v = rep.to_json();
        assert_eq!(v.get("controller").as_str(), Some("queue-threshold"));
        let tl = v.get("timeline").as_arr().unwrap();
        assert_eq!(tl.len(), 1);
        assert_eq!(tl[0].get("kind").as_str(), Some("scale-up"));
    }

    #[test]
    fn rejected_requests_leave_flight_and_gate_json() {
        let mut m = MetricsCollector::new();
        arrive(&mut m, 0, 0, 8, 1);
        arrive(&mut m, 1, 10, 8, 1);
        m.on_rejected(1);
        m.on_token(0, 100);
        m.on_finish(0, 100);
        assert_eq!(m.num_arrivals(), 2);
        assert_eq!(m.num_rejected(), 1);
        assert_eq!(m.num_in_flight(), 0, "rejection is terminal");
        let rep = m.report(1_000, &[]);
        assert_eq!(rep.rejected, 1);
        assert_eq!(rep.num_finished + rep.rejected, rep.num_requests);
        assert_eq!(rep.to_json().get("rejected").as_i64(), Some(1));
        // no-rejection reports omit the key (byte-compat)
        let rep = collect_one().report(10_000, &[]);
        assert_eq!(rep.rejected, 0);
        assert!(rep.to_json().get("rejected").is_null());
    }

    #[test]
    fn fault_windows_union_and_slo_split() {
        let mut m = MetricsCollector::new();
        // clear-time hit
        arrive(&mut m, 0, 0, 8, 1);
        m.on_token(0, 100);
        m.on_finish(0, 100);
        // two overlapping faults: union is [1000, 3000)
        m.on_fault_begin(1_000);
        m.on_fault_begin(1_500);
        m.on_fault_end(2_000);
        assert!(m.in_fault());
        // a finish landing inside the window, missing its TTFT target
        arrive(&mut m, 1, 1_000, 8, 1);
        let late = SloClass::Interactive.ttft_target_ns() * 2;
        m.on_token(1, 1_000 + late);
        m.on_finish(1, 1_000 + late);
        m.on_fault_end(3_000);
        assert!(!m.in_fault());
        let rep = m.report(10_000, &[]);
        let res = rep.resilience.expect("faults must produce a rollup");
        assert_eq!(res.faults, 2);
        assert_eq!(res.fault_ns, 2_000, "overlap counts once (union)");
        assert_eq!(res.finished_in_fault, 1);
        assert_eq!(res.slo_in_fault, 0.0);
        assert_eq!(res.slo_clear, 1.0);
        // an open window is closed at makespan
        let mut m2 = MetricsCollector::new();
        m2.on_fault_begin(4_000);
        assert_eq!(m2.report(10_000, &[]).resilience.unwrap().fault_ns, 6_000);
        // fault-free reports omit the resilience key (byte-compat)
        let rep = collect_one().report(10_000, &[]);
        assert!(rep.resilience.is_none());
        assert!(rep.to_json().get("resilience").is_null());
    }

    #[test]
    fn resilience_json_shape_includes_domains() {
        let mut m = MetricsCollector::new();
        m.on_fault_begin(100);
        m.on_fault_end(200);
        let mut rep = m.report(1_000, &[]);
        rep.resilience.as_mut().unwrap().domains.push(DomainReport {
            zone: "rack0".into(),
            instances: 2,
            downtime_ns: 100,
            availability: 0.95,
        });
        let v = rep.to_json();
        let res = v.get("resilience");
        assert_eq!(res.get("faults").as_i64(), Some(1));
        assert_eq!(res.get("fault_ns").as_i64(), Some(100));
        assert!(res.get("slo_in_fault").as_f64().is_some());
        let doms = res.get("domains").as_arr().unwrap();
        assert_eq!(doms.len(), 1);
        assert_eq!(doms[0].get("zone").as_str(), Some("rack0"));
        assert_eq!(doms[0].get("instances").as_i64(), Some(2));
        assert_eq!(doms[0].get("downtime_ns").as_i64(), Some(100));
    }

    #[test]
    fn slo_attainment_so_far_tracks_finishes() {
        let mut m = MetricsCollector::new();
        assert_eq!(m.slo_attainment_so_far(), 1.0, "vacuous before finishes");
        // one fast hit
        arrive(&mut m, 0, 0, 8, 1);
        m.on_token(0, 100);
        m.on_finish(0, 100);
        assert_eq!(m.num_arrivals(), 1);
        assert_eq!(m.slo_attainment_so_far(), 1.0);
        // one interactive miss
        arrive(&mut m, 1, 0, 8, 1);
        m.on_token(1, SloClass::Interactive.ttft_target_ns() * 2);
        m.on_finish(1, SloClass::Interactive.ttft_target_ns() * 2);
        assert!((m.slo_attainment_so_far() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn error_vs_reference() {
        let m = collect_one();
        let a = m.report(10_000, &[]);
        let mut b = a.clone();
        b.throughput_tps *= 1.10;
        let err = b.error_vs(&a);
        assert!((err.throughput_pct - 10.0).abs() < 1e-6);
        assert_eq!(err.tpot_pct, 0.0);
    }

    #[test]
    fn report_json_shape() {
        let rep = collect_one().report(10_000, &["default".into()]);
        let v = rep.to_json();
        assert_eq!(v.get("num_finished").as_i64(), Some(1));
        assert!(v.get("tpot_ns").get("mean").as_f64().is_some());
        assert!(v.get("goodput_tps").as_f64().is_some());
        let classes = v.get("slo_classes").as_arr().unwrap();
        assert_eq!(classes.len(), 1);
        assert_eq!(classes[0].get("class").as_str(), Some("interactive"));
        let tenants = v.get("tenants").as_arr().unwrap();
        assert_eq!(tenants[0].get("name").as_str(), Some("default"));
    }

    #[test]
    fn headline_from_json_matches_struct_extraction() {
        let rep = collect_one().report(10_000, &[]);
        let v = rep.to_json();
        // bit-equality, not approximate: the merge path's byte-identity
        // contract rides on the JSON round trip being lossless
        assert_eq!(
            headline_from_json(&v, "ttft_mean_ms"),
            Some(rep.ttft_ns.mean / 1e6)
        );
        assert_eq!(
            headline_from_json(&v, "tpot_mean_ms"),
            Some(rep.tpot_ns.mean / 1e6)
        );
        assert_eq!(
            headline_from_json(&v, "itl_mean_ms"),
            Some(rep.itl_ns.mean / 1e6)
        );
        assert_eq!(
            headline_from_json(&v, "throughput_tps"),
            Some(rep.throughput_tps)
        );
        assert_eq!(
            headline_from_json(&v, "makespan_s"),
            Some(rep.makespan as f64 / 1e9)
        );
        assert_eq!(headline_from_json(&v, "warp_factor"), None);
        assert_eq!(headline_from_json(&Value::Null, "ttft_mean_ms"), None);
    }
}
