//! Serving metrics: TTFT, TPOT, ITL, end-to-end latency, token throughput,
//! per-instance utilization, and cache statistics — the quantities Fig. 2
//! reports (average TPOT, ITL, and token generation throughput).
//!
//! Definitions (matching vLLM's benchmark conventions, which the paper
//! compares against):
//! * **TTFT** — arrival to first output token.
//! * **TPOT** — (end-to-end latency - TTFT) / (output tokens - 1).
//! * **ITL**  — individual gaps between consecutive output tokens.
//! * **Throughput** — total generated tokens / makespan.

use std::collections::HashMap;

use crate::sim::{nanos_to_secs, Nanos};
use crate::util::json::Value;
use crate::util::stats::{self, Summary};

/// Lifecycle timestamps for one request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: Nanos,
    pub dispatched: Option<Nanos>,
    pub instance: Option<usize>,
    pub token_times: Vec<Nanos>,
    pub finished: Option<Nanos>,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
    /// Prompt tokens served from the prefix cache (any tier).
    pub cached_tokens: u64,
}

impl RequestRecord {
    pub fn ttft(&self) -> Option<Nanos> {
        self.token_times.first().map(|&t| t - self.arrival)
    }

    pub fn e2e(&self) -> Option<Nanos> {
        self.finished.map(|f| f - self.arrival)
    }

    /// Time per output token (excluding the first).
    pub fn tpot(&self) -> Option<f64> {
        let e2e = self.e2e()? as f64;
        let ttft = self.ttft()? as f64;
        let n = self.token_times.len();
        if n <= 1 {
            return None;
        }
        Some((e2e - ttft) / (n - 1) as f64)
    }

    /// Inter-token latencies.
    pub fn itls(&self) -> Vec<f64> {
        self.token_times
            .windows(2)
            .map(|w| (w[1] - w[0]) as f64)
            .collect()
    }

    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }
}

/// Collects per-request lifecycle events during a simulation.
#[derive(Debug, Default)]
pub struct MetricsCollector {
    records: HashMap<u64, RequestRecord>,
    /// Per-instance busy time accumulation.
    busy: HashMap<usize, Nanos>,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_arrival(&mut self, id: u64, at: Nanos, prompt: u64, output: u64) {
        self.records.insert(
            id,
            RequestRecord {
                id,
                arrival: at,
                dispatched: None,
                instance: None,
                token_times: vec![],
                finished: None,
                prompt_tokens: prompt,
                output_tokens: output,
                cached_tokens: 0,
            },
        );
    }

    pub fn on_dispatch(&mut self, id: u64, at: Nanos, instance: usize) {
        if let Some(r) = self.records.get_mut(&id) {
            r.dispatched = Some(at);
            r.instance = Some(instance);
        }
    }

    pub fn on_cached(&mut self, id: u64, tokens: u64) {
        if let Some(r) = self.records.get_mut(&id) {
            r.cached_tokens = tokens;
        }
    }

    pub fn on_token(&mut self, id: u64, at: Nanos) {
        if let Some(r) = self.records.get_mut(&id) {
            r.token_times.push(at);
        }
    }

    pub fn on_finish(&mut self, id: u64, at: Nanos) {
        if let Some(r) = self.records.get_mut(&id) {
            r.finished = Some(at);
        }
    }

    pub fn on_busy(&mut self, instance: usize, dur: Nanos) {
        *self.busy.entry(instance).or_insert(0) += dur;
    }

    pub fn record(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(&id)
    }

    pub fn num_finished(&self) -> usize {
        self.records.values().filter(|r| r.is_finished()).count()
    }

    /// Build the final report. `makespan` is the simulation end time.
    pub fn report(&self, makespan: Nanos) -> Report {
        let finished: Vec<&RequestRecord> = {
            let mut v: Vec<&RequestRecord> =
                self.records.values().filter(|r| r.is_finished()).collect();
            v.sort_by_key(|r| r.id);
            v
        };
        let ttft: Vec<f64> = finished
            .iter()
            .filter_map(|r| r.ttft().map(|t| t as f64))
            .collect();
        let tpot: Vec<f64> = finished.iter().filter_map(|r| r.tpot()).collect();
        let itl: Vec<f64> = finished.iter().flat_map(|r| r.itls()).collect();
        let e2e: Vec<f64> = finished
            .iter()
            .filter_map(|r| r.e2e().map(|t| t as f64))
            .collect();
        let gen_tokens: u64 = finished.iter().map(|r| r.token_times.len() as u64).sum();
        let cached_tokens: u64 = finished.iter().map(|r| r.cached_tokens).sum();
        let secs = nanos_to_secs(makespan).max(1e-12);
        let utilization: HashMap<usize, f64> = self
            .busy
            .iter()
            .map(|(&i, &b)| (i, (b as f64 / makespan.max(1) as f64).min(1.0)))
            .collect();
        Report {
            num_requests: self.records.len(),
            num_finished: finished.len(),
            makespan,
            ttft_ns: Summary::of(&ttft),
            tpot_ns: Summary::of(&tpot),
            itl_ns: Summary::of(&itl),
            e2e_ns: Summary::of(&e2e),
            generated_tokens: gen_tokens,
            cached_tokens,
            throughput_tps: gen_tokens as f64 / secs,
            utilization,
        }
    }
}

/// Final simulation report (one Fig. 2 data point).
#[derive(Debug, Clone)]
pub struct Report {
    pub num_requests: usize,
    pub num_finished: usize,
    pub makespan: Nanos,
    pub ttft_ns: Summary,
    pub tpot_ns: Summary,
    pub itl_ns: Summary,
    pub e2e_ns: Summary,
    pub generated_tokens: u64,
    pub cached_tokens: u64,
    /// Output tokens per second.
    pub throughput_tps: f64,
    pub utilization: HashMap<usize, f64>,
}

impl Report {
    pub fn to_json(&self) -> Value {
        let sum = |s: &Summary| {
            Value::obj(vec![
                ("mean", Value::float(s.mean)),
                ("p50", Value::float(s.p50)),
                ("p90", Value::float(s.p90)),
                ("p99", Value::float(s.p99)),
                ("count", Value::int(s.count as i64)),
            ])
        };
        let mut util: Vec<(usize, f64)> =
            self.utilization.iter().map(|(&k, &v)| (k, v)).collect();
        util.sort_by_key(|&(k, _)| k);
        Value::obj(vec![
            ("num_requests", Value::int(self.num_requests as i64)),
            ("num_finished", Value::int(self.num_finished as i64)),
            ("makespan_ns", Value::int(self.makespan as i64)),
            ("ttft_ns", sum(&self.ttft_ns)),
            ("tpot_ns", sum(&self.tpot_ns)),
            ("itl_ns", sum(&self.itl_ns)),
            ("e2e_ns", sum(&self.e2e_ns)),
            ("generated_tokens", Value::int(self.generated_tokens as i64)),
            ("cached_tokens", Value::int(self.cached_tokens as i64)),
            ("throughput_tps", Value::float(self.throughput_tps)),
            (
                "utilization",
                Value::arr(
                    util.into_iter()
                        .map(|(k, v)| {
                            Value::obj(vec![
                                ("instance", Value::int(k as i64)),
                                ("busy", Value::float(v)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Mean absolute percentage error of headline metrics vs a reference
    /// report (used by Fig. 2 validation: TPOT, ITL, throughput).
    pub fn error_vs(&self, reference: &Report) -> ValidationError {
        ValidationError {
            tpot_pct: stats::ape(self.tpot_ns.mean, reference.tpot_ns.mean),
            itl_pct: stats::ape(self.itl_ns.mean, reference.itl_ns.mean),
            throughput_pct: stats::ape(self.throughput_tps, reference.throughput_tps),
            ttft_pct: stats::ape(self.ttft_ns.mean, reference.ttft_ns.mean),
        }
    }
}

/// Percentage errors of a simulated report against a reference run.
#[derive(Debug, Clone, Copy)]
pub struct ValidationError {
    pub tpot_pct: f64,
    pub itl_pct: f64,
    pub throughput_pct: f64,
    pub ttft_pct: f64,
}

impl ValidationError {
    pub fn mean(&self) -> f64 {
        (self.tpot_pct + self.itl_pct + self.throughput_pct) / 3.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect_one() -> MetricsCollector {
        let mut m = MetricsCollector::new();
        m.on_arrival(0, 1000, 32, 4);
        m.on_dispatch(0, 1500, 0);
        m.on_token(0, 2000);
        m.on_token(0, 2500);
        m.on_token(0, 3100);
        m.on_token(0, 3600);
        m.on_finish(0, 3600);
        m
    }

    #[test]
    fn ttft_tpot_itl() {
        let m = collect_one();
        let r = m.record(0).unwrap();
        assert_eq!(r.ttft(), Some(1000));
        assert_eq!(r.e2e(), Some(2600));
        // tpot = (2600-1000)/3
        assert!((r.tpot().unwrap() - 1600.0 / 3.0).abs() < 1e-9);
        assert_eq!(r.itls(), vec![500.0, 600.0, 500.0]);
    }

    #[test]
    fn single_token_has_no_tpot() {
        let mut m = MetricsCollector::new();
        m.on_arrival(0, 0, 8, 1);
        m.on_token(0, 100);
        m.on_finish(0, 100);
        assert!(m.record(0).unwrap().tpot().is_none());
    }

    #[test]
    fn report_aggregates() {
        let m = collect_one();
        let rep = m.report(10_000);
        assert_eq!(rep.num_finished, 1);
        assert_eq!(rep.generated_tokens, 4);
        assert!((rep.throughput_tps - 4.0 / 1e-5).abs() < 1.0);
        assert_eq!(rep.ttft_ns.mean, 1000.0);
    }

    #[test]
    fn unfinished_requests_excluded() {
        let mut m = collect_one();
        m.on_arrival(1, 2000, 16, 8);
        m.on_token(1, 3000);
        let rep = m.report(10_000);
        assert_eq!(rep.num_requests, 2);
        assert_eq!(rep.num_finished, 1);
    }

    #[test]
    fn utilization_capped() {
        let mut m = collect_one();
        m.on_busy(0, 5_000);
        m.on_busy(0, 4_000);
        let rep = m.report(10_000);
        assert!((rep.utilization[&0] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn error_vs_reference() {
        let m = collect_one();
        let a = m.report(10_000);
        let mut b = a.clone();
        b.throughput_tps *= 1.10;
        let err = b.error_vs(&a);
        assert!((err.throughput_pct - 10.0).abs() < 1e-6);
        assert_eq!(err.tpot_pct, 0.0);
    }

    #[test]
    fn report_json_shape() {
        let rep = collect_one().report(10_000);
        let v = rep.to_json();
        assert_eq!(v.get("num_finished").as_i64(), Some(1));
        assert!(v.get("tpot_ns").get("mean").as_f64().is_some());
    }
}
